"""Characterization-loop-driven kernel autotuning (DESIGN.md §4 point 1).

The paper's motivation for tree models over simulators: "estimate the
performance and impact of an architectural change *quickly*" (§1). We close
the loop: a tree trained on (static metrics + candidate schedule params) ->
modeled time becomes a microsecond-scale cost model; at run time we sweep
the candidate schedules through the tree and pick the argmin — optionally
verifying the winner with the full schedule simulation.

Used by models/moe.py (block size / backend choice for expert GEMMs) and by
examples/characterize.py for user matrices.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .csr import CSR
from . import metrics as metrics_mod
from .decision_tree import DecisionTreeRegressor
from .dataset import Matrix
from .perfmodel import (run_spadd_model, run_spgemm_model, run_spmv_model,
                        run_spmv_sell_model)
from .platforms import Platform

BLOCK_SIZES = (32, 64, 128, 256)
ELL_QUANTILES = (0.8, 0.95, 1.0)
SLICE_HEIGHTS = (4, 8, 16)      # SELL slice heights swept as a schedule axis
SELL_SIGMA = 64                 # sorting window (block-rows); fixed, not swept
DENSE_DENSITY_THRESHOLD = 0.25  # above this, a dense matmul wins trivially
TUNER_TREE_DEPTH = 14           # cost-tree depth shared by fit() and refit()
# fit(prune_top_k="auto"): grids past this size prune themselves with the
# provisional tree (ROADMAP item — fit cost must not scale with the full
# layout x block_size x quantile x slice_height product as axes grow).
PRUNE_GRID_THRESHOLD = 50
AUTO_PRUNE_TOP_K = 8
# Names of the schedule-parameter features appended to the static metrics.
CFG_FEATURES = ("cfg_block_size", "cfg_ell_quantile", "cfg_slice_height",
                "cfg_n_rhs")


@dataclasses.dataclass(frozen=True)
class Schedule:
    backend: str          # "dense" | "bsr"
    block_size: int
    ell_quantile: float
    layout: str = "ell"   # "ell" (global padding) | "sell" (sliced)
    slice_height: int = 0  # SELL C; 0 = n/a for the global-ELL layout
    n_rhs: int = 1        # RHS tile width (1 = SpMV, >1 = the SpMM path)

    def as_features(self) -> List[float]:
        return [float(self.block_size), float(self.ell_quantile),
                float(self.slice_height), float(self.n_rhs)]


def candidate_schedules(n_rhs: int = 1) -> List[Schedule]:
    ell = [Schedule("bsr", bs, q, n_rhs=n_rhs)
           for bs, q in itertools.product(BLOCK_SIZES, ELL_QUANTILES)]
    sell = [Schedule("bsr", bs, 1.0, layout="sell", slice_height=c, n_rhs=n_rhs)
            for bs, c in itertools.product(BLOCK_SIZES, SLICE_HEIGHTS)]
    return ell + sell


def _modeled_time(kernel: str, A: CSR, platform: Platform, sched: Schedule) -> float:
    if kernel == "spmv":
        if sched.layout == "sell":
            _, t, _ = run_spmv_sell_model(A, platform, sched.block_size,
                                          sched.slice_height, SELL_SIGMA,
                                          sched.n_rhs)
        else:
            _, t, _ = run_spmv_model(A, platform, sched.block_size,
                                     sched.ell_quantile, sched.n_rhs)
    elif kernel == "spgemm":
        _, t, _ = run_spgemm_model(A, A, platform, sched.block_size)
    else:
        B = A.transpose() if A.shape[0] == A.shape[1] else A
        _, t, _ = run_spadd_model(A, B, platform, sched.block_size)
    return t["t_total"]


class ScheduleTuner:
    """Tree-backed cost model over (matrix metrics, schedule params)."""

    def __init__(self, kernel: str, platform: Platform, n_rhs: int = 1) -> None:
        self.kernel = kernel
        self.platform = platform
        self.n_rhs = max(int(n_rhs), 1)  # workload RHS width (SpMM path)
        self.tree: Optional[DecisionTreeRegressor] = None
        self.feature_names: List[str] = []
        self.fit_simulations_ = 0
        # Training rows kept so refit() can fold in online feedback
        # (SelectorService.retraining_examples) without re-simulating.
        self._train_rows: Optional[np.ndarray] = None
        self._train_ys: Optional[np.ndarray] = None

    def fit(self, mats: Sequence[Matrix], max_mats: int = 64, seed: int = 0,
            prune_top_k="auto", bootstrap_mats: int = 8,
            candidates: Optional[Sequence[Schedule]] = None
            ) -> "ScheduleTuner":
        """Train the cost tree on (static metrics, schedule params) rows.

        With ``prune_top_k`` set, the candidate sweep is itself pruned by the
        tree (ROADMAP item): the first ``bootstrap_mats`` matrices sweep every
        candidate and train a provisional tree; each later matrix only
        simulates the provisional tree's top-``k`` candidates, so fit() cost
        stops scaling with the full layout x block_size x quantile x
        slice_height product. ``fit_simulations_`` records the number of
        schedule simulations actually run.

        The default ``prune_top_k="auto"`` turns pruning on
        (``AUTO_PRUNE_TOP_K``) once the candidate grid exceeds
        ``PRUNE_GRID_THRESHOLD`` schedules and sweeps fully below it; pass
        an int to force a k or ``None`` to force the full sweep.
        ``candidates`` overrides the swept grid (defaults to
        ``candidate_schedules(n_rhs)``).
        """
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(mats))[:max_mats]
        candidates = (candidate_schedules(self.n_rhs) if candidates is None
                      else list(candidates))
        if isinstance(prune_top_k, str):
            if prune_top_k != "auto":
                raise ValueError(f"prune_top_k must be an int, None, or "
                                 f"'auto', got {prune_top_k!r}")
            prune_top_k = (AUTO_PRUNE_TOP_K
                           if len(candidates) > PRUNE_GRID_THRESHOLD else None)
        rows, ys = [], []
        feature_names: Optional[List[str]] = None
        provisional: Optional[DecisionTreeRegressor] = None
        self.fit_simulations_ = 0
        for count, i in enumerate(idx):
            _, _, A = mats[int(i)]
            static = metrics_mod.characterize(A)
            if feature_names is None:
                feature_names = list(static) + list(CFG_FEATURES)
            base = [static[k] for k in feature_names[: -len(CFG_FEATURES)]]
            scheds = candidates
            if provisional is not None:
                k = max(int(prune_top_k), 1)
                scored = provisional.predict(np.asarray(
                    [base + s.as_features() for s in candidates]))
                scheds = [candidates[j] for j in np.argsort(scored)[:k]]
            for sched in scheds:
                rows.append(base + sched.as_features())
                ys.append(np.log10(max(_modeled_time(self.kernel, A, self.platform,
                                                     sched), 1e-12)))
                self.fit_simulations_ += 1
            if (prune_top_k is not None and provisional is None
                    and count + 1 >= min(bootstrap_mats, len(idx))):
                provisional = DecisionTreeRegressor(max_depth=TUNER_TREE_DEPTH).fit(
                    np.asarray(rows), np.asarray(ys))
        self.feature_names = feature_names or []
        self._train_rows = np.asarray(rows)
        self._train_ys = np.asarray(ys)
        self.tree = DecisionTreeRegressor(max_depth=TUNER_TREE_DEPTH).fit(
            self._train_rows, self._train_ys)
        return self

    def refit(self, extra_rows: Sequence[Sequence[float]],
              extra_ys: Sequence[float]) -> "ScheduleTuner":
        """Fold online feedback rows (same static+cfg feature space as
        ``fit``) into the training set and retrain the tree — the explicit
        retraining path ``SelectorService.refit`` drives; no simulation
        re-runs."""
        assert self.tree is not None, "call fit() before refit()"
        rows = np.concatenate([self._train_rows,
                               np.asarray(extra_rows, dtype=float)], axis=0)
        ys = np.concatenate([self._train_ys,
                             np.asarray(extra_ys, dtype=float)], axis=0)
        self._train_rows, self._train_ys = rows, ys
        self.tree = DecisionTreeRegressor(max_depth=TUNER_TREE_DEPTH).fit(rows, ys)
        return self

    def predict_time(self, static: Dict[str, float], sched: Schedule) -> float:
        assert self.tree is not None, "call fit() first"
        n_static = len(self.feature_names) - len(CFG_FEATURES)
        x = [static[k] for k in self.feature_names[:n_static]] + sched.as_features()
        return float(10 ** self.tree.predict(np.asarray([x]))[0])

    def select(self, A: CSR, verify_top: int = 2) -> Tuple[Schedule, Dict[str, float]]:
        """Pick the best schedule for ``A``; verify top candidates by simulation."""
        if A.density() > DENSE_DENSITY_THRESHOLD:
            return Schedule("dense", 128, 1.0, n_rhs=self.n_rhs), {"reason": 1.0}
        static = metrics_mod.characterize(A)
        scored = sorted(
            ((self.predict_time(static, s), s)
             for s in candidate_schedules(self.n_rhs)),
            key=lambda p: p[0])
        best_t, best_s = scored[0]
        # verification pass on the top candidates (tree is approximate)
        verified = [(_modeled_time(self.kernel, A, self.platform, s), s)
                    for _, s in scored[:verify_top]]
        verified.sort(key=lambda p: p[0])
        vt, vs = verified[0]
        return vs, {"tree_time_s": best_t, "verified_time_s": vt}


def select_moe_block_size(tokens_per_expert: np.ndarray, d_model: int,
                          platform: Platform) -> int:
    """MoE grouped-GEMM tile choice from the imbalance metric (Eq. 5 reuse).

    High expert imbalance -> smaller tiles waste less on ragged group tails;
    balanced routing -> full MXU tiles. This mirrors the paper's finding that
    imbalance is the limiting factor for partitioned sparse work.
    """
    imb = metrics_mod.partition_imbalance(tokens_per_expert.astype(np.float64),
                                          max(len(tokens_per_expert), 1))
    if imb > 1.0:
        return 64
    if imb > 0.5:
        return 128
    return 256
