"""Schedule-level counters: the TPU analogue of the paper's PMCs (§3.2).

On Arm the paper reads perf counters (stalls, cache misses, MPKI). A TPU
kernel's performance is fixed by its *schedule*: which HBM<->VMEM copies
happen, how many MXU tiles execute, how much of each tile is padding. We
therefore "profile" a kernel by simulating its block schedule over the real
matrix and counting:

  executed_blocks / useful_flops / executed_flops  (padding waste = the
      frontend-stall / branch-flush analogue: dead lanes from irregular rows)
  vmem_hits / vmem_misses over the gathered operand  (the backend-stall /
      cache-miss analogue: LRU residency of x-segments or B block-rows)
  hbm_bytes  (DRAM traffic)
  grid_imbalance  (Eq. 5 applied to per-grid-cell work)

These counters are (a) features for the decision trees alongside the static
metrics, and (b) inputs to the roofline execution-time model (perfmodel.py).
They depend on the matrix *and* the platform (VMEM size), exactly like PMCs
depend on input and machine.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict

import numpy as np

from .csr import CSR, BSR, ELLBSR, SELLBSR, ell_block_cap
from .metrics import count_dominated_before, partition_imbalance, prev_occurrence
from .platforms import Platform

BYTES_F32 = 4


class _LRU:
    """LRU residency model for VMEM-cached operand segments.

    Per-access reference implementation. The counters below run the
    vectorized ``lru_hit_mask`` instead (identical results, no Python loop
    over accesses); tests assert the two stay equivalent.
    """

    def __init__(self, capacity_segments: int) -> None:
        self.cap = max(int(capacity_segments), 1)
        self.store: "OrderedDict[int, None]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def access(self, key: int) -> bool:
        if key in self.store:
            self.store.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        self.store[key] = None
        if len(self.store) > self.cap:
            self.store.popitem(last=False)
        return False


def lru_hit_mask(stream: np.ndarray, capacity: int) -> np.ndarray:
    """Exact per-access LRU hit/miss mask, vectorized.

    An access hits a capacity-``capacity`` LRU iff its stack distance — the
    number of distinct keys accessed since the previous access to the same
    key — is < capacity. With prev[i] the previous same-key position, every
    j <= prev[i] trivially satisfies prev[j] <= prev[i] (prev[j] < j), so

        d(i) = #{j < i : prev[j] <= prev[i]} - (prev[i] + 1)

    counts exactly the first-in-window accesses in (prev[i], i), i.e. the
    distinct keys of the window. Two exact shortcuts keep the common cases
    O(n log n) sort-bound: if the stream has <= capacity distinct keys every
    reuse hits, and any window shorter than ``capacity`` accesses cannot
    contain ``capacity`` distinct keys, so only long-window reuses need the
    full dominance count.
    """
    stream = np.asarray(stream)
    n = stream.size
    if n == 0:
        return np.zeros(0, dtype=bool)
    cap = max(int(capacity), 1)
    prev = prev_occurrence(stream)
    reused = prev >= 0
    if int(n - reused.sum()) <= cap:  # #first-accesses == #distinct keys
        return reused
    hits = reused & ((np.arange(n) - prev - 1) < cap)
    hard = np.nonzero(reused & ~hits)[0]
    if hard.size:
        d = count_dominated_before(prev, hard) - (prev[hard] + 1)
        hits[hard] = d < cap
    return hits


# The paper pins synthetic matrices at 16M rows so the SpMV dense vector
# (64 MB) exceeds every LLC (§3.3). Our corpus is scaled down for this
# container, so the machine model's VMEM must scale with it to preserve the
# paper's cache-to-working-set ratios (A64FX 32MB / x=64MB etc. -> here
# v4 0.5x, v5e 1x, v5p 2x of the dense vector).
PAPER_N_ROWS = 16_000_000


def vmem_scale_for(n_rows: int) -> float:
    return min(n_rows / PAPER_N_ROWS, 1.0)


def _vmem_budget_segments(platform: Platform, segment_bytes: int,
                          vmem_scale: float = 1.0, frac: float = 0.5) -> int:
    """Half of (scaled) VMEM is modeled as available for the gathered
    operand; the rest holds streamed tiles and double-buffers."""
    budget = platform.vmem_bytes * vmem_scale * frac
    return max(int(budget) // max(segment_bytes, 1), 1)


# ---------------------------------------------------------------------------
# SpMV: y = A @ x over an ELL-BSR schedule (kernels/bsr_spmv)
# ---------------------------------------------------------------------------

def spmv_counters(csr: CSR, platform: Platform, block_size: int = 128,
                  ell_quantile: float = 1.0,
                  vmem_scale: float | None = None,
                  n_rhs: int = 1) -> Dict[str, float]:
    if vmem_scale is None:
        vmem_scale = vmem_scale_for(csr.n_rows)
    n_rhs = max(int(n_rhs), 1)
    bsr = BSR.from_csr(csr, block_size)
    bpr = bsr.blocks_per_row()
    ell = ELLBSR.from_bsr(bsr, ell_block_cap(bpr, ell_quantile))
    bs = block_size
    executed_blocks = ell.block_indices.size
    useful_flops = 2.0 * csr.nnz * n_rhs
    executed_flops = 2.0 * executed_blocks * bs * bs * n_rhs
    dropped_nnz = max(csr.nnz - int(np.count_nonzero(
        ell.blocks[ell.block_indices[ell.block_indices < bsr.n_blocks]])), 0)

    # x-segment residency: one (bs, n_rhs) segment per block column, LRU
    # over VMEM.
    seg_bytes = bs * n_rhs * BYTES_F32
    hit = lru_hit_mask(bsr.block_cols,
                       _vmem_budget_segments(platform, seg_bytes, vmem_scale))
    lru_hits, lru_misses = int(hit.sum()), int(hit.size - hit.sum())

    a_bytes = executed_blocks * bs * bs * BYTES_F32
    x_bytes = lru_misses * seg_bytes
    y_bytes = bsr.n_block_rows * bs * n_rhs * BYTES_F32
    return {
        "executed_blocks": float(executed_blocks),
        "useful_flops": useful_flops,
        "executed_flops": executed_flops,
        "padding_fraction": 1.0 - useful_flops / max(executed_flops, 1.0),
        "vmem_hits": float(lru_hits),
        "vmem_misses": float(lru_misses),
        "vmem_miss_rate": lru_misses / max(lru_hits + lru_misses, 1),
        "hbm_bytes": float(a_bytes + x_bytes + y_bytes),
        "gather_bytes": float(x_bytes),
        "grid_imbalance": partition_imbalance(bpr, 16),
        "dropped_nnz_fraction": dropped_nnz / max(csr.nnz, 1),
        "ell_padding_fraction": ell.ell_padding_fraction(),
    }


# ---------------------------------------------------------------------------
# SELL SpMV/SpMM: the sliced schedule (kernels/bsr_spmv SELL path)
# ---------------------------------------------------------------------------

def sell_spmv_counters(csr: CSR, platform: Platform, block_size: int = 128,
                       slice_height: int = 8, sigma: int = 64, n_rhs: int = 1,
                       vmem_scale: float | None = None) -> Dict[str, float]:
    """Counters for the SELL-C-sigma bucketed schedule, optionally with a
    multi-RHS tile of ``n_rhs`` columns (the SpMM path).

    vs ``spmv_counters``: executed work is the true cell count (padding only
    up to each slice's own max), and every A/x/y byte is amortized over the
    RHS width — one A-block DMA feeds ``n_rhs`` columns of output.
    """
    if vmem_scale is None:
        vmem_scale = vmem_scale_for(csr.n_rows)
    n_rhs = max(int(n_rhs), 1)
    bsr = BSR.from_csr(csr, block_size)
    sell = SELLBSR.from_bsr(bsr, slice_height, sigma)
    bs = block_size
    n_cells = sell.n_cells
    useful_flops = 2.0 * csr.nnz * n_rhs
    executed_flops = 2.0 * n_cells * bs * bs * n_rhs

    # x-segment residency: one (bs, n_rhs) segment per block column, accessed
    # in cell (= sorted slice) order.
    seg_bytes = bs * n_rhs * BYTES_F32
    zero_idx = sell.blocks.shape[0] - 1
    hit = lru_hit_mask(sell.cell_col[sell.cell_block != zero_idx],
                       _vmem_budget_segments(platform, seg_bytes, vmem_scale))
    lru_hits, lru_misses = int(hit.sum()), int(hit.size - hit.sum())

    a_bytes = n_cells * bs * bs * BYTES_F32
    x_bytes = lru_misses * seg_bytes
    y_bytes = bsr.n_block_rows * bs * n_rhs * BYTES_F32
    per_row_cells = np.bincount(sell.cell_row,
                                minlength=max(bsr.n_block_rows, 1))
    return {
        "executed_blocks": float(n_cells),
        "useful_flops": useful_flops,
        "executed_flops": executed_flops,
        "padding_fraction": 1.0 - useful_flops / max(executed_flops, 1.0),
        "vmem_hits": float(lru_hits),
        "vmem_misses": float(lru_misses),
        "vmem_miss_rate": lru_misses / max(lru_hits + lru_misses, 1),
        "hbm_bytes": float(a_bytes + x_bytes + y_bytes),
        "gather_bytes": float(x_bytes),
        "grid_imbalance": partition_imbalance(per_row_cells, 16),
        "sell_padding_fraction": sell.sell_padding_fraction(),
        "ell_padding_fraction": _global_ell_padding(bsr),
        "slice_imbalance": sell.slice_imbalance(),
        "n_rhs": float(n_rhs),
    }


def _global_ell_padding(bsr: BSR) -> float:
    """Slot waste of the global-ELL schedule on the same matrix — the
    before-point the SELL counters are compared against."""
    bpr = bsr.blocks_per_row()
    if bpr.size == 0:
        return 0.0
    slots = bpr.size * max(int(bpr.max()), 1)
    return 1.0 - float(bpr.sum()) / max(slots, 1)


# ---------------------------------------------------------------------------
# SpGEMM numeric: C = A @ B, Gustavson over block rows (kernels/bsr_spgemm)
# ---------------------------------------------------------------------------

def spgemm_counters(a: CSR, b: CSR, platform: Platform, block_size: int = 128,
                    vmem_scale: float | None = None) -> Dict[str, float]:
    if vmem_scale is None:
        vmem_scale = vmem_scale_for(a.n_rows)
    bsr_a = BSR.from_csr(a, block_size)
    bsr_b = BSR.from_csr(b, block_size)
    bs = block_size
    b_bpr = bsr_b.blocks_per_row()
    b_row_bytes = b_bpr * bs * bs * BYTES_F32

    # Useful flops: 2 * sum over nnz a_ij of nnz(B row j).
    b_row_nnz = np.zeros(b.n_rows + 1, dtype=np.int64)
    b_row_nnz[: b.n_rows] = b.row_lengths()
    useful_flops = 2.0 * float(b_row_nnz[np.minimum(a.col_idxs, b.n_rows - 1)].sum())

    # Executed flops: each A block (i,k) multiplies B block-row k densely.
    a_block_cols = bsr_a.block_cols
    safe_cols = np.minimum(a_block_cols, bsr_b.n_block_rows - 1)
    executed_flops = float((2 * bs * bs * bs) * b_bpr[safe_cols].sum())

    # B block-row residency in VMEM (the paper's "poor reuse of the RHS").
    mean_row_bytes = float(b_row_bytes.mean()) if b_row_bytes.size else 1.0
    hit = lru_hit_mask(safe_cols, _vmem_budget_segments(
        platform, int(max(mean_row_bytes, 1)), vmem_scale))
    lru_hits, lru_misses = int(hit.sum()), int(hit.size - hit.sum())
    gather_bytes = float(b_row_bytes[safe_cols[~hit]].sum())

    a_bytes = bsr_a.n_blocks * bs * bs * BYTES_F32
    # C traffic: accumulate block rows (symbolic union size).
    c_blocks = _spgemm_symbolic_blocks(bsr_a, bsr_b)
    c_bytes = c_blocks * bs * bs * BYTES_F32
    return {
        "executed_blocks": float(bsr_a.n_blocks),
        "useful_flops": useful_flops,
        "executed_flops": max(executed_flops, useful_flops),
        "padding_fraction": 1.0 - useful_flops / max(executed_flops, 1.0),
        "vmem_hits": float(lru_hits),
        "vmem_misses": float(lru_misses),
        "vmem_miss_rate": lru_misses / max(lru_hits + lru_misses, 1),
        "hbm_bytes": float(a_bytes + gather_bytes + c_bytes),
        "gather_bytes": gather_bytes,
        "grid_imbalance": partition_imbalance(bsr_a.blocks_per_row(), 16),
        "c_blocks": float(c_blocks),
    }


def _spgemm_symbolic_blocks(bsr_a: BSR, bsr_b: BSR) -> int:
    """Symbolic phase at block granularity: |union of B block-rows per A row|."""
    total = 0
    b_rows: Dict[int, np.ndarray] = {}
    for br in range(bsr_b.n_block_rows):
        b_rows[br] = bsr_b.block_cols[bsr_b.block_ptrs[br]: bsr_b.block_ptrs[br + 1]]
    for br in range(bsr_a.n_block_rows):
        ks = bsr_a.block_cols[bsr_a.block_ptrs[br]: bsr_a.block_ptrs[br + 1]]
        if ks.size == 0:
            continue
        cols = np.concatenate([b_rows.get(int(k), np.empty(0, np.int32)) for k in ks])
        total += int(np.unique(cols).size)
    return total


# ---------------------------------------------------------------------------
# SpADD: C = A + B block-union schedule (kernels/bsr_spadd)
# ---------------------------------------------------------------------------

def spadd_counters(a: CSR, b: CSR, platform: Platform, block_size: int = 128,
                   vmem_scale: float | None = None) -> Dict[str, float]:
    bsr_a = BSR.from_csr(a, block_size)
    bsr_b = BSR.from_csr(b, block_size)
    bs = block_size
    union_blocks = 0
    inter_blocks = 0
    per_row_union = np.zeros(bsr_a.n_block_rows, dtype=np.int64)
    for br in range(bsr_a.n_block_rows):
        ca = set(bsr_a.block_cols[bsr_a.block_ptrs[br]: bsr_a.block_ptrs[br + 1]].tolist())
        cb = set(bsr_b.block_cols[bsr_b.block_ptrs[br]: bsr_b.block_ptrs[br + 1]].tolist()) \
            if br < bsr_b.n_block_rows else set()
        u = len(ca | cb)
        union_blocks += u
        inter_blocks += len(ca & cb)
        per_row_union[br] = u

    useful_flops = float(a.nnz + b.nnz)  # one add/copy per input nonzero
    executed_flops = float(union_blocks * bs * bs)  # one vector add per union block
    a_bytes = bsr_a.n_blocks * bs * bs * BYTES_F32
    b_bytes = bsr_b.n_blocks * bs * bs * BYTES_F32
    c_bytes = union_blocks * bs * bs * BYTES_F32
    # ELL regularization of the union structure: the irregularity cost.
    mx = int(per_row_union.max()) if per_row_union.size else 1
    ell_slots = bsr_a.n_block_rows * max(mx, 1)
    return {
        "executed_blocks": float(union_blocks),
        "useful_flops": useful_flops,
        "executed_flops": max(executed_flops, useful_flops),
        "padding_fraction": 1.0 - useful_flops / max(executed_flops, 1.0),
        "vmem_hits": 0.0,  # streaming kernel: no gathered operand (paper §2.1.4)
        "vmem_misses": 0.0,
        "vmem_miss_rate": 0.0,
        "hbm_bytes": float(a_bytes + b_bytes + c_bytes),
        "gather_bytes": 0.0,
        "grid_imbalance": partition_imbalance(per_row_union, 16),
        "ell_slot_waste": 1.0 - union_blocks / max(ell_slots, 1),
        "merge_overlap": inter_blocks / max(union_blocks, 1),
    }


COUNTER_NAMES = (
    "padding_fraction", "vmem_miss_rate", "grid_imbalance", "hbm_bytes",
    "gather_bytes", "executed_flops",
)


# ---------------------------------------------------------------------------
# Sharded execution: per-shard static features (DESIGN.md §10)
# ---------------------------------------------------------------------------

def shard_counters(csr: CSR, bounds) -> list:
    """Per-shard static features for a contiguous row split.

    ``bounds`` is the (n_shards + 1)-entry row boundary vector of a
    ``repro.sparse.partition.RowPartition``. Each shard gets the Eq. 5
    story at two scales: its own deviation from the ideal nnz share
    (``nnz_share_dev`` — the cross-shard imbalance the partitioner
    minimizes) and the within-shard ``grid_imbalance`` of its rows (the
    per-shard schedule problem the selector solves shard by shard — skewed
    matrices yield structurally different shards, hence different
    fingerprints, hence different layouts/block sizes per shard).
    """
    bounds = np.asarray(bounds, np.int64)
    lengths = csr.row_lengths()
    csum = np.concatenate([[0], np.cumsum(lengths)])
    n_parts = bounds.size - 1
    total = float(csum[-1])
    ideal = total / max(n_parts, 1)
    out = []
    for i in range(n_parts):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        seg = lengths[lo:hi]
        nnz = float(csum[hi] - csum[lo])
        mean = float(seg.mean()) if seg.size else 0.0
        std = float(seg.std()) if seg.size else 0.0
        out.append({
            "rows": float(hi - lo),
            "nnz": nnz,
            "nnz_share_dev": abs(nnz - ideal) / ideal if ideal > 0 else 0.0,
            "mean_row_length": mean,
            "cv_row_length": std / mean if mean > 0 else 0.0,
            "grid_imbalance": partition_imbalance(seg, 16),
        })
    return out
