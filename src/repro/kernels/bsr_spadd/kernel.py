"""Branch-free block-union SpADD Pallas kernel (paper Alg. 3, DESIGN §2).

The paper finds SpADD bottlenecked by branch mispredictions in the
data-dependent row merge. TPUs have no branch predictor, so we restructure:
a host-side *symbolic* phase (mirroring SpGEMM's symbolic/numeric split,
§2.1.3) computes the union block structure of C and, per output block, the
source indices into A's and B's block arrays (sentinel -> trailing zero
block). The *numeric* phase below is then a perfectly regular stream:

  C.blocks[k] = A.blocks[ia[k]] + B.blocks[ib[k]]

One grid cell per tile of output blocks; both gathers are scalar-prefetched
DMAs. The merge's "branch entropy" cost survives only as union inflation
(counters.spadd_counters.ell_slot_waste) — measurable, not speculative.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spadd_kernel(ia_ref, ib_ref, a_ref, b_ref, c_ref):
    del ia_ref, ib_ref
    c_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spadd_pallas(ia: jax.Array, ib: jax.Array, a_blocks: jax.Array,
                     b_blocks: jax.Array, interpret: bool = False) -> jax.Array:
    """C.blocks = A.blocks[ia] + B.blocks[ib] (block gather-add).

    Args:
      ia: (n_c_blocks,) int32 into ``a_blocks`` (last = zeros sentinel).
      ib: (n_c_blocks,) int32 into ``b_blocks`` (last = zeros sentinel).
      a_blocks: (n_a + 1, bs, bs) float32.  b_blocks: (n_b + 1, bs, bs).
    Returns:
      (n_c_blocks, bs, bs) float32.
    """
    n_c = ia.shape[0]
    bs = a_blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_c,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda k, ia, ib: (ia[k], 0, 0)),
            pl.BlockSpec((1, bs, bs), lambda k, ia, ib: (ib[k], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bs), lambda k, ia, ib: (k, 0, 0)),
    )
    return pl.pallas_call(
        _spadd_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_c, bs, bs), jnp.float32),
        interpret=interpret,
    )(ia, ib, a_blocks, b_blocks)
