from .ops import bsr_spadd, spadd_symbolic  # noqa: F401
from .ref import ref_block_union_add  # noqa: F401
