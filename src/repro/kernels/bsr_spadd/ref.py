"""Pure-jnp oracle for the block-union SpADD numeric phase."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def ref_block_union_add(ia: jax.Array, ib: jax.Array, a_blocks: jax.Array,
                        b_blocks: jax.Array) -> jax.Array:
    return a_blocks[ia] + b_blocks[ib]
