"""Public SpADD op: symbolic (host) + numeric (kernel) phases."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.csr import CSR, BSR
from ..common import resolve_backend
from .kernel import bsr_spadd_pallas
from .ref import ref_block_union_add


def spadd_symbolic(bsr_a: BSR, bsr_b: BSR) -> Tuple[np.ndarray, np.ndarray,
                                                    np.ndarray, np.ndarray]:
    """Symbolic phase: union block structure of C = A + B.

    Returns (c_block_ptrs, c_block_cols, ia, ib) where ia/ib index into the
    block arrays of A/B with the zeros-sentinel convention (n_blocks = the
    appended zero block).
    """
    n_br = max(bsr_a.n_block_rows, bsr_b.n_block_rows)
    a_sent, b_sent = bsr_a.n_blocks, bsr_b.n_blocks
    c_cols, ia, ib = [], [], []
    c_ptrs = np.zeros(n_br + 1, dtype=np.int64)
    for br in range(n_br):
        amap = {}
        if br < bsr_a.n_block_rows:
            for k in range(bsr_a.block_ptrs[br], bsr_a.block_ptrs[br + 1]):
                amap[int(bsr_a.block_cols[k])] = k
        bmap = {}
        if br < bsr_b.n_block_rows:
            for k in range(bsr_b.block_ptrs[br], bsr_b.block_ptrs[br + 1]):
                bmap[int(bsr_b.block_cols[k])] = k
        union = sorted(set(amap) | set(bmap))
        for col in union:
            c_cols.append(col)
            ia.append(amap.get(col, a_sent))
            ib.append(bmap.get(col, b_sent))
        c_ptrs[br + 1] = len(c_cols)
    return (c_ptrs, np.asarray(c_cols, np.int32),
            np.asarray(ia, np.int32), np.asarray(ib, np.int32))


def bsr_spadd(a: CSR, b: CSR, block_size: int = 128, backend: str = "auto",
              schedule=None) -> BSR:
    """C = A + B via block-union schedule; returns C as BSR.

    ``schedule``: an optional pre-selected ``core.autotune.Schedule`` (from
    the selector service); its block size overrides ``block_size``.
    """
    if schedule is not None:
        if schedule.backend == "dense":
            raise ValueError("dense schedules have no BSR path; dispatch a "
                             "dense matmul instead")
        block_size = schedule.block_size
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    backend = resolve_backend(backend)
    bsr_a = BSR.from_csr(a, block_size)
    bsr_b = BSR.from_csr(b, block_size)
    c_ptrs, c_cols, ia, ib = spadd_symbolic(bsr_a, bsr_b)
    bs = block_size
    a_blocks = jnp.concatenate(
        [jnp.asarray(bsr_a.blocks), jnp.zeros((1, bs, bs), jnp.float32)])
    b_blocks = jnp.concatenate(
        [jnp.asarray(bsr_b.blocks), jnp.zeros((1, bs, bs), jnp.float32)])
    ia_j, ib_j = jnp.asarray(ia), jnp.asarray(ib)
    if ia.size == 0:
        c_blocks = np.zeros((0, bs, bs), np.float32)
    elif backend == "jnp":
        c_blocks = np.asarray(ref_block_union_add(ia_j, ib_j, a_blocks, b_blocks))
    else:
        c_blocks = np.asarray(bsr_spadd_pallas(
            ia_j, ib_j, a_blocks, b_blocks, interpret=(backend == "interpret")))
    return BSR(c_ptrs, c_cols, c_blocks, a.shape, block_size)
