"""SpADD symbolic phase (host, vectorized) + the legacy entry-point shim.

The union block structure is computed with numpy bulk ops (repeat /
unique / scatter) — no per-row Python loops; host prep is on the serving
path. The numeric phase lives behind the facade
(``repro.sparse.plan("spadd", ...)``).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ...core.csr import CSR, BSR


def _block_keys(bsr: BSR, n_bc: int) -> np.ndarray:
    rows = np.repeat(np.arange(bsr.n_block_rows, dtype=np.int64),
                     bsr.blocks_per_row())
    return rows * n_bc + bsr.block_cols.astype(np.int64)


def spadd_symbolic(bsr_a: BSR, bsr_b: BSR) -> Tuple[np.ndarray, np.ndarray,
                                                    np.ndarray, np.ndarray]:
    """Symbolic phase: union block structure of C = A + B.

    Returns (c_block_ptrs, c_block_cols, ia, ib) where ia/ib index into the
    block arrays of A/B with the zeros-sentinel convention (n_blocks = the
    appended zero block).
    """
    n_br = max(bsr_a.n_block_rows, bsr_b.n_block_rows)
    n_bc = max(-(-bsr_a.shape[1] // bsr_a.block_size),
               -(-bsr_b.shape[1] // bsr_b.block_size))
    keys_a = _block_keys(bsr_a, n_bc)
    keys_b = _block_keys(bsr_b, n_bc)
    uk, inv = np.unique(np.concatenate([keys_a, keys_b]),
                        return_inverse=True)
    n_c = int(uk.size)
    ia = np.full(n_c, bsr_a.n_blocks, dtype=np.int32)
    ib = np.full(n_c, bsr_b.n_blocks, dtype=np.int32)
    ia[inv[: keys_a.size]] = np.arange(keys_a.size, dtype=np.int32)
    ib[inv[keys_a.size:]] = np.arange(keys_b.size, dtype=np.int32)
    c_cols = (uk % n_bc).astype(np.int32)
    c_ptrs = np.zeros(n_br + 1, dtype=np.int64)
    np.add.at(c_ptrs, uk // n_bc + 1, 1)
    c_ptrs = np.cumsum(c_ptrs)
    return c_ptrs, c_cols, ia, ib


def bsr_spadd(a: CSR, b: CSR, block_size: int = 128, backend: str = "auto",
              schedule=None) -> BSR:
    """C = A + B; returns C as BSR.

    .. deprecated:: use ``repro.sparse.plan("spadd", (a, b), ...)`` — this
       shim delegates there (DESIGN.md §8 migration table).
    """
    from ...sparse import plan
    return plan("spadd", (a, b), schedule=schedule, backend=backend,
                block_size=block_size).execute()
