"""Shared backend dispatch for kernels.

"pallas"    — compile for TPU (requires a TPU backend at runtime)
"interpret" — run the same kernel body in the Pallas interpreter (CPU OK);
              used by tests as the kernel-execution oracle check
"jnp"       — pure-jnp implementation with identical semantics; this is the
              path the pjit/dry-run model code uses (TPU Pallas calls cannot
              lower for the CPU mesh of this container)
"auto"      — "pallas" on TPU, "jnp" elsewhere
"""
from __future__ import annotations

import jax

VALID_BACKENDS = ("auto", "pallas", "interpret", "jnp")


def resolve_backend(backend: str) -> str:
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend must be one of {VALID_BACKENDS}, got {backend!r}")
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "jnp"
