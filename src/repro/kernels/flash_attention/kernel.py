"""Chunked online-softmax attention (FlashAttention dataflow, TPU tiling).

Used by the prefill hot spot of the LM substrate. grid = (batch*heads,
q_tiles, kv_tiles) with the kv axis innermost; running max / sum-exp / accum
live in VMEM scratch so the softmax never materializes the (S, S) score
matrix — the memory-roofline fix for the 32k-prefill shapes (§Perf).

VMEM per cell at (bq, bk, d) = (128, 128, 128): q, k, v tiles + acc + stats
~ 5 x 64 KB x 2 buffers ~ 640 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # (bq, d)
    k = k_ref[0]                                  # (bk, d)
    v = v_ref[0]                                  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]                           # (bq,)
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                              "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False
                           ) -> jax.Array:
    """softmax(q k^T / sqrt(d)) v without materializing scores.

    Args:  q/k/v: (BH, S, D) float32 (batch*heads flattened; GQA expansion
    happens in the wrapper).  Returns: (BH, S, D) float32.
    """
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_q, s // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)
