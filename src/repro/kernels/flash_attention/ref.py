"""Pure-jnp oracle: exact softmax attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal",))
def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones(s.shape[-2:], bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)
