"""Public attention op with backend dispatch."""
from __future__ import annotations

import jax

from ..common import resolve_backend
from .kernel import flash_attention_pallas
from .ref import ref_attention


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, backend: str = "auto") -> jax.Array:
    """(BH, S, D) attention; see kernel.py for the TPU schedule."""
    backend = resolve_backend(backend)
    if backend == "jnp":
        return ref_attention(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k,
                                  interpret=(backend == "interpret"))
