"""Public attention op with backend dispatch."""
from __future__ import annotations

import jax

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, backend: str = "auto") -> jax.Array:
    """(BH, S, D) attention; see kernel.py for the TPU schedule.

    .. deprecated:: use ``plan("flash_attention", (), causal=...)`` — this
    shim delegates there (DESIGN.md §8)."""
    from ...sparse import plan
    return plan("flash_attention", (), backend=backend, causal=causal,
                block_q=block_q, block_k=block_k).execute(q, k, v)
