from .ops import flash_attention  # noqa: F401
from .ref import ref_attention  # noqa: F401
