"""Pure-jnp oracle for the grouped GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("tile_m",))
def ref_gmm(tile_expert: jax.Array, x: jax.Array, w: jax.Array,
            tile_m: int = 128) -> jax.Array:
    m, _ = x.shape
    token_expert = jnp.repeat(tile_expert, tile_m)          # (M,)
    w_tok = w[token_expert]                                 # (M, K, N) gather
    return jnp.einsum("mk,mkn->mn", x, w_tok)
