from .ops import moe_gmm, route_and_pad  # noqa: F401
from .ref import ref_gmm  # noqa: F401
