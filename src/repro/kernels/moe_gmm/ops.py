"""Public grouped-GEMM op + host-side routing/padding helper."""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np

def route_and_pad(tokens: np.ndarray, expert_of_token: np.ndarray, n_experts: int,
                  tile_m: int = 128) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort tokens by expert; pad each group to a tile_m multiple.

    Returns (x_sorted_padded (M, K), tile_expert (M/tile_m,),
    inverse_index (M,) with -1 on padding rows) so outputs can be
    scattered back: out_tokens[i] = out_padded[inverse_index == i].
    """
    t, k = tokens.shape
    order = np.argsort(expert_of_token, kind="stable")
    counts = np.bincount(expert_of_token, minlength=n_experts)
    padded_counts = np.maximum(-(-counts // tile_m) * tile_m, tile_m)
    m_total = int(padded_counts.sum())
    x = np.zeros((m_total, k), tokens.dtype)
    inv = np.full(m_total, -1, dtype=np.int64)
    tile_expert = np.repeat(np.arange(n_experts), padded_counts // tile_m)
    offs = np.concatenate([[0], np.cumsum(padded_counts)])
    src = 0
    for e in range(n_experts):
        grp = order[src: src + counts[e]]
        x[offs[e]: offs[e] + counts[e]] = tokens[grp]
        inv[offs[e]: offs[e] + counts[e]] = grp
        src += counts[e]
    return x, tile_expert.astype(np.int32), inv


def moe_gmm(tile_expert: jax.Array, x: jax.Array, w: jax.Array,
            tile_m: int = 128, tile_n: int = 128, tile_k: int = 128,
            backend: str = "auto") -> jax.Array:
    """.. deprecated:: use ``plan("moe_gmm", (tile_expert,), tile_m=...)`` —
    this shim delegates there (DESIGN.md §8)."""
    from ...sparse import plan
    return plan("moe_gmm", (tile_expert,), backend=backend, tile_m=tile_m,
                tile_n=tile_n, tile_k=tile_k).execute(x, w)
