"""Ragged grouped GEMM for MoE expert compute (MegaBlocks-style).

This is the framework integration of SpChar's imbalance analysis
(DESIGN.md §4): tokens sorted by expert form ragged groups = the paper's
nnz-per-row partition problem (Eq. 5). Groups are padded to the m-tile so
the schedule is regular; raggedness shows up as tile padding, counted by
``core.counters``-style metrics and arbitrated by ``autotune``.

Layout: x is pre-sorted by expert and padded, (M, K); w is (E, K, N);
``tile_expert`` maps each m-tile to its expert (scalar prefetch).

grid = (m_tiles, n_tiles, k_tiles), k innermost: the C tile accumulates in
VMEM across the K reduction; the expert weight tile is gathered per m-tile
via the scalar-prefetched expert id. VMEM per cell at (tm, tn, tk) =
(128, 128, 128) f32: 3 x 64 KB x 2 buffers ~ 384 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(eids_ref, x_ref, w_ref, o_ref):
    del eids_ref
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k",
                                              "interpret"))
def moe_gmm_pallas(tile_expert: jax.Array, x: jax.Array, w: jax.Array,
                   tile_m: int = 128, tile_n: int = 128, tile_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """out[t*tm:(t+1)*tm] = x[t*tm:(t+1)*tm] @ w[tile_expert[t]].

    Args:
      tile_expert: (m_tiles,) int32 expert id per m-tile.
      x: (M, K) float32, M % tile_m == 0, sorted by expert, group-padded.
      w: (E, K, N) float32 expert weights.
    Returns:
      (M, N) float32.
    """
    m, kdim = x.shape
    e, _, n = w.shape
    assert m % tile_m == 0 and kdim % tile_k == 0 and n % tile_n == 0, (
        m, kdim, n, tile_m, tile_k, tile_n)
    grid = (m // tile_m, n // tile_n, kdim // tile_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda mi, ni, ki, eids: (mi, ki)),
            pl.BlockSpec((1, tile_k, tile_n),
                         lambda mi, ni, ki, eids: (eids[mi], ki, ni)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n),
                               lambda mi, ni, ki, eids: (mi, ni)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(tile_expert, x, w)
