"""ELL-BSR SpMV Pallas TPU kernel (paper Alg. 1 adapted per §4.4 / DESIGN §2).

Schedule
  grid = (n_block_rows, max_blocks_per_row); the slot axis is innermost so
  the output block-row stays resident in VMEM across accumulation steps.
  Scalar-prefetched ``block_indices`` / ``block_cols`` drive the BlockSpec
  index maps: the A tile for grid cell (i, j) is ``blocks[idx[i, j]]`` and
  the x segment is ``x[cols[i, j]]`` — data-dependent HBM->VMEM DMA with no
  data-dependent control flow in the kernel body. Padding slots point at a
  trailing all-zeros block (ELLBSR invariant), so irregular rows cost dead
  MXU lanes (the counters' ``padding_fraction``) instead of branches: the
  paper's branch-misprediction bottleneck transformed into a measurable,
  tree-visible quantity.

VMEM per grid cell: (1+1 double-buffered) x (bs*bs + bs + bs) * 4B; at
bs=128 that is ~132 KB, far under VMEM, leaving room for deeper pipelining.
MXU alignment wants bs in {128, 256}; smaller bs trades padding for
underutilized systolic lanes (autotune.py arbitrates via the tree model).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(idx_ref, cols_ref, blk_ref, x_ref, y_ref):
    del idx_ref, cols_ref  # consumed by the index maps
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # (bs, bs) @ (bs,) accumulated into the resident output block-row.
    y_ref[...] += jnp.dot(
        blk_ref[0], x_ref[0], preferred_element_type=jnp.float32
    )[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmv_pallas(block_indices: jax.Array, block_cols: jax.Array,
                    blocks: jax.Array, x_blocks: jax.Array,
                    interpret: bool = False) -> jax.Array:
    """y = A @ x with A in ELL-BSR layout.

    Args:
      block_indices: (n_br, mb) int32 — index into ``blocks``; padding slots
        hold ``blocks.shape[0] - 1`` (the all-zeros block).
      block_cols:    (n_br, mb) int32 — block-column of each slot.
      blocks:        (n_blocks + 1, bs, bs) float32, last block all-zeros.
      x_blocks:      (n_block_cols, bs) float32 — dense vector, blocked.
    Returns:
      (n_br, bs) float32 — blocked result vector.
    """
    n_br, mb = block_indices.shape
    bs = blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_br, mb),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i, j, idx, cols: (idx[i, j], 0, 0)),
            pl.BlockSpec((1, bs), lambda i, j, idx, cols: (cols[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda i, j, idx, cols: (i, 0)),
    )
    return pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_br, bs), jnp.float32),
        interpret=interpret,
    )(block_indices, block_cols, blocks, x_blocks)
