"""ELL/SELL-BSR SpMV + multi-RHS SpMM Pallas TPU kernels (paper Alg. 1
adapted per §4.4 / DESIGN §2).

Schedules
  ELL (global padding, DESIGN §2.2)
    grid = (n_block_rows, max_blocks_per_row); the slot axis is innermost so
    the output block-row stays resident in VMEM across accumulation steps.
    Scalar-prefetched ``block_indices`` / ``block_cols`` drive the BlockSpec
    index maps: the A tile for grid cell (i, j) is ``blocks[idx[i, j]]`` and
    the x segment is ``x[cols[i, j]]`` — data-dependent HBM->VMEM DMA with no
    data-dependent control flow in the kernel body. Padding slots point at a
    trailing all-zeros block (ELLBSR invariant), so irregular rows cost dead
    MXU lanes (the counters' ``padding_fraction``) instead of branches: the
    paper's branch-misprediction bottleneck transformed into a measurable,
    tree-visible quantity.

  SELL (sliced padding, DESIGN §2.3)
    grid = (n_cells,) — a ragged schedule flattened on the host. Three
    scalar-prefetched streams drive the index maps: ``cell_block[t]`` /
    ``cell_col[t]`` pick the A tile and x segment of step t, and
    ``cell_row[t]`` (nondecreasing: the host emits a row's cells
    consecutively in SELL row-sorted order) picks the resident output tile,
    which Pallas flushes exactly when the row index advances. The kernel
    writes in sorted order; the op scatters back through ``row_perm``. The
    grid runs sum_s C*w_s steps instead of n_block_rows*max_w — the padding
    eliminated by slicing is grid steps that simply never launch.

  SpMM (multi-RHS)
    Same two schedules with x blocked as (n_block_cols, bs, k): one A-tile
    DMA now feeds a (bs, bs) @ (bs, k) MXU op, amortizing A traffic across k
    right-hand sides — the reuse the paper finds missing from SpMV.

VMEM per grid cell: (1+1 double-buffered) x (bs*bs + bs*k + bs*k) * 4B; at
bs=128, k=8 that is ~148 KB, far under VMEM, leaving room for deeper
pipelining. MXU alignment wants bs in {128, 256}; smaller bs trades padding
for underutilized systolic lanes (autotune.py arbitrates via the tree model).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ell_kernel(idx_ref, cols_ref, blk_ref, x_ref, y_ref):
    del idx_ref, cols_ref  # consumed by the index maps
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # (bs, bs) @ (bs,) or (bs, bs) @ (bs, k), accumulated into the resident
    # output block-row.
    y_ref[...] += jnp.dot(
        blk_ref[0], x_ref[0], preferred_element_type=jnp.float32
    )[None]


def _sell_kernel(idx_ref, cols_ref, rows_ref, blk_ref, x_ref, y_ref):
    del idx_ref, cols_ref  # consumed by the index maps
    t = pl.program_id(0)
    first = jnp.logical_or(t == 0, rows_ref[t] != rows_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        blk_ref[0], x_ref[0], preferred_element_type=jnp.float32
    )[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmv_pallas(block_indices: jax.Array, block_cols: jax.Array,
                    blocks: jax.Array, x_blocks: jax.Array,
                    interpret: bool = False) -> jax.Array:
    """y = A @ x with A in ELL-BSR layout.

    Args:
      block_indices: (n_br, mb) int32 — index into ``blocks``; padding slots
        hold ``blocks.shape[0] - 1`` (the all-zeros block).
      block_cols:    (n_br, mb) int32 — block-column of each slot.
      blocks:        (n_blocks + 1, bs, bs) float32, last block all-zeros.
      x_blocks:      (n_block_cols, bs) float32 — dense vector, blocked.
    Returns:
      (n_br, bs) float32 — blocked result vector.
    """
    n_br, mb = block_indices.shape
    bs = blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_br, mb),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i, j, idx, cols: (idx[i, j], 0, 0)),
            pl.BlockSpec((1, bs), lambda i, j, idx, cols: (cols[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda i, j, idx, cols: (i, 0)),
    )
    return pl.pallas_call(
        _ell_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_br, bs), jnp.float32),
        interpret=interpret,
    )(block_indices, block_cols, blocks, x_blocks)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmm_pallas(block_indices: jax.Array, block_cols: jax.Array,
                    blocks: jax.Array, x_blocks: jax.Array,
                    interpret: bool = False) -> jax.Array:
    """Y = A @ X with A in ELL-BSR layout and X multi-RHS.

    Args:
      x_blocks: (n_block_cols, bs, k) float32 — dense RHS, row-blocked; k is
        the lane-aligned RHS tile the A-block DMA is amortized over.
    Returns:
      (n_br, bs, k) float32 — blocked result rows.
    """
    n_br, mb = block_indices.shape
    bs = blocks.shape[-1]
    k = x_blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_br, mb),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda i, j, idx, cols: (idx[i, j], 0, 0)),
            pl.BlockSpec((1, bs, k), lambda i, j, idx, cols: (cols[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, k), lambda i, j, idx, cols: (i, 0, 0)),
    )
    return pl.pallas_call(
        _ell_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_br, bs, k), jnp.float32),
        interpret=interpret,
    )(block_indices, block_cols, blocks, x_blocks)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "interpret"))
def bsr_spmv_sell_pallas(cell_block: jax.Array, cell_col: jax.Array,
                         cell_row: jax.Array, blocks: jax.Array,
                         x_blocks: jax.Array, n_block_rows: int,
                         interpret: bool = False) -> jax.Array:
    """y_sorted = P A @ x with A in SELL-BSR layout (bucketed schedule).

    Args:
      cell_block: (n_cells,) int32 — A tile per grid step; pads hold the
        all-zeros block index.
      cell_col:   (n_cells,) int32 — x segment per grid step.
      cell_row:   (n_cells,) int32 — *sorted* output block-row per step,
        nondecreasing so the output tile is revisited only consecutively.
      blocks:     (n_blocks + 1, bs, bs) float32, last block all-zeros.
      x_blocks:   (n_block_cols, bs) float32.
      n_block_rows: static output row count.
    Returns:
      (n_block_rows, bs) float32 in SELL-sorted row order; scatter back with
      ``SELLBSR.row_perm``.
    """
    n_cells = cell_block.shape[0]
    bs = blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_cells,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda t, idx, cols, rows: (idx[t], 0, 0)),
            pl.BlockSpec((1, bs), lambda t, idx, cols, rows: (cols[t], 0)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda t, idx, cols, rows: (rows[t], 0)),
    )
    return pl.pallas_call(
        _sell_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, bs), jnp.float32),
        interpret=interpret,
    )(cell_block, cell_col, cell_row, blocks, x_blocks)


@functools.partial(jax.jit, static_argnames=("n_block_rows", "interpret"))
def bsr_spmm_sell_pallas(cell_block: jax.Array, cell_col: jax.Array,
                         cell_row: jax.Array, blocks: jax.Array,
                         x_blocks: jax.Array, n_block_rows: int,
                         interpret: bool = False) -> jax.Array:
    """Y_sorted = P A @ X: the SELL bucketed schedule with a multi-RHS tile.

    Same contract as ``bsr_spmv_sell_pallas`` with x_blocks of shape
    (n_block_cols, bs, k); returns (n_block_rows, bs, k) in sorted order.
    """
    n_cells = cell_block.shape[0]
    bs = blocks.shape[-1]
    k = x_blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_cells,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda t, idx, cols, rows: (idx[t], 0, 0)),
            pl.BlockSpec((1, bs, k), lambda t, idx, cols, rows: (cols[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, k), lambda t, idx, cols, rows: (rows[t], 0, 0)),
    )
    return pl.pallas_call(
        _sell_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows, bs, k), jnp.float32),
        interpret=interpret,
    )(cell_block, cell_col, cell_row, blocks, x_blocks)
