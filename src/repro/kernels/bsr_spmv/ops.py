"""Public SpMV/SpMM ops: host-side format prep + layout/backend dispatch.

Two layouts (DESIGN.md §2.2-2.3):
  ELLBSR  — globally padded, regular (n_br, max_blocks) grid.
  SELLBSR — sliced padding; ragged schedule flattened to one grid step per
            cell, results scattered back through the stored row permutation.
Both expose ``jnp`` / ``interpret`` / ``pallas`` backends; ``bsr_spmv`` and
``bsr_spmm`` dispatch on the container type.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ...core.autotune import SELL_SIGMA, Schedule
from ...core.csr import CSR, BSR, ELLBSR, SELLBSR, ell_block_cap
from ..common import resolve_backend
from .kernel import (bsr_spmm_pallas, bsr_spmm_sell_pallas, bsr_spmv_pallas,
                     bsr_spmv_sell_pallas)
from .ref import (ref_bsr_spmm, ref_bsr_spmm_sell, ref_bsr_spmv,
                  ref_bsr_spmv_sell)

SparseLayout = Union[ELLBSR, SELLBSR]


def ell_device_arrays(ell: ELLBSR) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Move an ELLBSR container to device arrays for the kernel."""
    return (jnp.asarray(ell.block_indices, jnp.int32),
            jnp.asarray(ell.block_cols, jnp.int32),
            jnp.asarray(ell.blocks, jnp.float32),
            ell.block_size)


def sell_device_arrays(sell: SELLBSR
                       ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Move a SELLBSR container's cell schedule to device arrays."""
    return (jnp.asarray(sell.cell_block, jnp.int32),
            jnp.asarray(sell.cell_col, jnp.int32),
            jnp.asarray(sell.cell_row, jnp.int32),
            jnp.asarray(sell.blocks, jnp.float32))


def prepare(csr: CSR, block_size: int = 128, max_blocks: int | None = None) -> ELLBSR:
    return ELLBSR.from_bsr(BSR.from_csr(csr, block_size), max_blocks)


def prepare_sell(csr: CSR, block_size: int = 128, slice_height: int = 8,
                 sigma: int = 64) -> SELLBSR:
    return SELLBSR.from_bsr(BSR.from_csr(csr, block_size), slice_height, sigma)


def prepare_with_schedule(csr: CSR, sched: Schedule,
                          sigma: int = SELL_SIGMA) -> SparseLayout:
    """Build the container a pre-selected autotune/selector ``Schedule``
    names: the glue between the selection service and the kernels."""
    if sched.backend == "dense":
        raise ValueError("dense schedules have no sparse container; "
                         "dispatch to a dense matmul instead")
    if sched.layout == "sell":
        return prepare_sell(csr, sched.block_size,
                            max(sched.slice_height, 1), sigma)
    bsr = BSR.from_csr(csr, sched.block_size)
    return ELLBSR.from_bsr(bsr, ell_block_cap(bsr.blocks_per_row(),
                                              sched.ell_quantile))


def bsr_spmv_scheduled(csr: CSR, x: jax.Array, sched: Schedule,
                       backend: str = "auto") -> jax.Array:
    """y = A @ x (or Y = A @ X when x is 2-D) under a pre-selected
    ``Schedule``: prep + layout dispatch + backend in one call, so serving
    code routes a (matrix, schedule) pair straight to the kernels."""
    x = jnp.asarray(x)
    if sched.backend == "dense":
        dense = jnp.asarray(csr.to_dense())
        return dense @ x.astype(jnp.float32)
    a = prepare_with_schedule(csr, sched)
    if x.ndim == 2:
        return bsr_spmm(a, x, backend=backend)
    return bsr_spmv(a, x, backend=backend)


def _x_blocked(a: SparseLayout, x: jax.Array) -> jax.Array:
    """Pad the dense vector to the block grid and reshape to (n_bc, bs)."""
    bs = a.block_size
    n_bc = -(-a.shape[1] // bs)
    x_pad = jnp.zeros((n_bc * bs,), jnp.float32).at[: a.shape[1]].set(
        x.astype(jnp.float32))
    return x_pad.reshape(n_bc, bs)


def _rhs_blocked(a: SparseLayout, X: jax.Array, rhs_tile: int) -> jax.Array:
    """Pad the dense RHS to the block grid / RHS tile: (n_bc, bs, k_pad)."""
    bs = a.block_size
    n_bc = -(-a.shape[1] // bs)
    k = X.shape[1]
    k_pad = -(-k // rhs_tile) * rhs_tile
    X_pad = jnp.zeros((n_bc * bs, k_pad), jnp.float32)
    X_pad = X_pad.at[: a.shape[1], :k].set(X.astype(jnp.float32))
    return X_pad.reshape(n_bc, bs, k_pad)


def _scatter_rows(sell: SELLBSR, y_sorted: jax.Array) -> jax.Array:
    """Undo the SELL row sort: sorted position i holds original block-row
    ``row_perm[i]``."""
    perm = jnp.asarray(sell.row_perm, jnp.int32)
    return jnp.zeros_like(y_sorted).at[perm].set(y_sorted)


def bsr_spmv(a: SparseLayout, x: jax.Array, backend: str = "auto") -> jax.Array:
    """y = A @ x for A in ELL-BSR or SELL-BSR form; x is the dense
    (n_cols,) vector.

    Returns a dense (n_rows,) vector (unpadded).
    """
    backend = resolve_backend(backend)
    x_blocks = _x_blocked(a, x)
    if isinstance(a, SELLBSR):
        idx, cols, rows, blocks = sell_device_arrays(a)
        n_br = a.n_block_rows
        if backend == "jnp":
            y = ref_bsr_spmv_sell(idx, cols, rows, blocks, x_blocks, n_br)
        else:
            y = bsr_spmv_sell_pallas(idx, cols, rows, blocks, x_blocks, n_br,
                                     interpret=(backend == "interpret"))
        y = _scatter_rows(a, y)
    else:
        idx, cols, blocks, _ = ell_device_arrays(a)
        if backend == "jnp":
            y = ref_bsr_spmv(idx, cols, blocks, x_blocks)
        else:
            y = bsr_spmv_pallas(idx, cols, blocks, x_blocks,
                                interpret=(backend == "interpret"))
    return y.reshape(-1)[: a.shape[0]]


def bsr_spmm(a: SparseLayout, X: jax.Array, backend: str = "auto",
             rhs_tile: int | None = None) -> jax.Array:
    """Y = A @ X for A in ELL-BSR or SELL-BSR form; X is dense (n_cols, k).

    The k axis is padded up to ``rhs_tile`` (lane-aligned: 128 for the
    compiled Pallas path, 8 otherwise) so one A-block DMA feeds a
    (bs, bs) @ (bs, k) MXU op — A traffic amortized across the RHS width.
    Returns dense (n_rows, k) (unpadded).
    """
    backend = resolve_backend(backend)
    if rhs_tile is None:
        rhs_tile = 128 if backend == "pallas" else 8
    k = X.shape[1]
    x_blocks = _rhs_blocked(a, X, rhs_tile)
    if isinstance(a, SELLBSR):
        idx, cols, rows, blocks = sell_device_arrays(a)
        n_br = a.n_block_rows
        if backend == "jnp":
            y = ref_bsr_spmm_sell(idx, cols, rows, blocks, x_blocks, n_br)
        else:
            y = bsr_spmm_sell_pallas(idx, cols, rows, blocks, x_blocks, n_br,
                                     interpret=(backend == "interpret"))
        y = _scatter_rows(a, y)
    else:
        idx, cols, blocks, _ = ell_device_arrays(a)
        if backend == "jnp":
            y = ref_bsr_spmm(idx, cols, blocks, x_blocks)
        else:
            y = bsr_spmm_pallas(idx, cols, blocks, x_blocks,
                                interpret=(backend == "interpret"))
    return y.reshape(y.shape[0] * y.shape[1], -1)[: a.shape[0], :k]


def spmv_oracle(csr: CSR, x: np.ndarray) -> np.ndarray:
    """CSR-semantics oracle (paper Alg. 1), dense math."""
    return csr.to_dense() @ np.asarray(x, np.float32)


def spmm_oracle(csr: CSR, X: np.ndarray) -> np.ndarray:
    """CSR-semantics multi-RHS oracle, dense math."""
    return csr.to_dense() @ np.asarray(X, np.float32)
