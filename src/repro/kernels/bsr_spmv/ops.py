"""Legacy SpMV/SpMM entry points — thin shims over the plan/execute facade.

The real dispatch (layout/backend/x-blocking) moved to
``repro.sparse.ops_builtin``; construction moved to
``repro.sparse.SparseTensor.from_csr``. These wrappers keep the historical
signatures working and delegate (DESIGN.md §8 migration table):

    prepare / prepare_sell / prepare_with_schedule
        -> SparseTensor.from_csr(csr, schedule=...) (.build_container for
           the bare host container these shims still return)
    bsr_spmv(a, x) / bsr_spmm(a, X)
        -> plan("spmv"/"spmm", (a,)).execute(x)
    bsr_spmv_scheduled(csr, x, sched)
        -> plan("spmv"/"spmm", (csr,), schedule=sched).execute(x)

The oracle helpers and device-array exporters remain here for tests.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ...core.autotune import SELL_SIGMA, Schedule
from ...core.csr import CSR, ELLBSR, SELLBSR

SparseLayout = Union[ELLBSR, SELLBSR]


def ell_device_arrays(ell: ELLBSR) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Move an ELLBSR container to device arrays for the kernel."""
    return (jnp.asarray(ell.block_indices, jnp.int32),
            jnp.asarray(ell.block_cols, jnp.int32),
            jnp.asarray(ell.blocks, jnp.float32),
            ell.block_size)


def sell_device_arrays(sell: SELLBSR
                       ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Move a SELLBSR container's cell schedule to device arrays."""
    return (jnp.asarray(sell.cell_block, jnp.int32),
            jnp.asarray(sell.cell_col, jnp.int32),
            jnp.asarray(sell.cell_row, jnp.int32),
            jnp.asarray(sell.blocks, jnp.float32))


def prepare(csr: CSR, block_size: int = 128, max_blocks: int | None = None) -> ELLBSR:
    """.. deprecated:: use ``SparseTensor.from_csr`` (returns the device
    pytree; this shim returns the bare host container)."""
    from ...sparse import SparseTensor
    return SparseTensor.build_container(
        csr, Schedule("bsr", block_size, 1.0), max_blocks=max_blocks)


def prepare_sell(csr: CSR, block_size: int = 128, slice_height: int = 8,
                 sigma: int = 64) -> SELLBSR:
    """.. deprecated:: use ``SparseTensor.from_csr(..., layout="sell")``."""
    from ...sparse import SparseTensor
    return SparseTensor.build_container(
        csr, Schedule("bsr", block_size, 1.0, layout="sell",
                      slice_height=slice_height), sigma=sigma)


def prepare_with_schedule(csr: CSR, sched: Schedule,
                          sigma: int = SELL_SIGMA) -> SparseLayout:
    """.. deprecated:: use ``SparseTensor.from_csr(csr, schedule=sched)``."""
    if sched.backend == "dense":
        raise ValueError("dense schedules have no sparse container; "
                         "dispatch to a dense matmul instead")
    from ...sparse import SparseTensor
    return SparseTensor.build_container(csr, sched, sigma=sigma)


def bsr_spmv_scheduled(csr: CSR, x: jax.Array, sched: Schedule,
                       backend: str = "auto") -> jax.Array:
    """.. deprecated:: use ``plan("spmv", (csr,), schedule=sched)``."""
    from ...sparse import plan
    x = jnp.asarray(x)
    op = "spmm" if x.ndim == 2 else "spmv"
    return plan(op, (csr,), schedule=sched, backend=backend).execute(x)


def bsr_spmv(a: SparseLayout, x: jax.Array, backend: str = "auto") -> jax.Array:
    """y = A @ x for a prepared ELL/SELL container.

    .. deprecated:: use ``plan("spmv", (a,))`` — this shim delegates there.
    """
    from ...sparse import plan
    return plan("spmv", (a,), backend=backend).execute(x)


def bsr_spmm(a: SparseLayout, X: jax.Array, backend: str = "auto",
             rhs_tile: int | None = None) -> jax.Array:
    """Y = A @ X for a prepared ELL/SELL container (multi-RHS).

    .. deprecated:: use ``plan("spmm", (a,))`` — this shim delegates there.
    """
    from ...sparse import plan
    return plan("spmm", (a,), backend=backend,
                rhs_tile=rhs_tile).execute(X)


def spmv_oracle(csr: CSR, x: np.ndarray) -> np.ndarray:
    """CSR-semantics oracle (paper Alg. 1), dense math."""
    return csr.to_dense() @ np.asarray(x, np.float32)


def spmm_oracle(csr: CSR, X: np.ndarray) -> np.ndarray:
    """CSR-semantics multi-RHS oracle, dense math."""
    return csr.to_dense() @ np.asarray(X, np.float32)
