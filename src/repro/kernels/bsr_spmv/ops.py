"""Public SpMV op: host-side format prep + backend dispatch."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.csr import CSR, BSR, ELLBSR
from ..common import resolve_backend
from .kernel import bsr_spmv_pallas
from .ref import ref_bsr_spmv


def ell_device_arrays(ell: ELLBSR) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Move an ELLBSR container to device arrays for the kernel."""
    return (jnp.asarray(ell.block_indices, jnp.int32),
            jnp.asarray(ell.block_cols, jnp.int32),
            jnp.asarray(ell.blocks, jnp.float32),
            ell.block_size)


def prepare(csr: CSR, block_size: int = 128, max_blocks: int | None = None) -> ELLBSR:
    return ELLBSR.from_bsr(BSR.from_csr(csr, block_size), max_blocks)


def bsr_spmv(ell: ELLBSR, x: jax.Array, backend: str = "auto") -> jax.Array:
    """y = A @ x for A in ELL-BSR form; x is the dense (n_cols,) vector.

    Returns a dense (n_rows,) vector (unpadded).
    """
    backend = resolve_backend(backend)
    bs = ell.block_size
    n_bc = -(-ell.shape[1] // bs)
    x_pad = jnp.zeros((n_bc * bs,), jnp.float32).at[: ell.shape[1]].set(
        x.astype(jnp.float32))
    x_blocks = x_pad.reshape(n_bc, bs)
    idx, cols, blocks, _ = ell_device_arrays(ell)
    if backend == "jnp":
        y = ref_bsr_spmv(idx, cols, blocks, x_blocks)
    else:
        y = bsr_spmv_pallas(idx, cols, blocks, x_blocks,
                            interpret=(backend == "interpret"))
    return y.reshape(-1)[: ell.shape[0]]


def spmv_oracle(csr: CSR, x: np.ndarray) -> np.ndarray:
    """CSR-semantics oracle (paper Alg. 1), dense math."""
    return csr.to_dense() @ np.asarray(x, np.float32)
