from .ops import (bsr_spmm, bsr_spmv, bsr_spmv_scheduled,  # noqa: F401
                  ell_device_arrays, prepare, prepare_sell,
                  prepare_with_schedule, sell_device_arrays)
from .ref import (ref_bsr_spmm, ref_bsr_spmm_sell, ref_bsr_spmv,  # noqa: F401
                  ref_bsr_spmv_sell)
