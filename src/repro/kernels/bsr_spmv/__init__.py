from .ops import (bsr_spmm, bsr_spmv, ell_device_arrays, prepare,  # noqa: F401
                  prepare_sell, sell_device_arrays)
from .ref import (ref_bsr_spmm, ref_bsr_spmm_sell, ref_bsr_spmv,  # noqa: F401
                  ref_bsr_spmv_sell)
