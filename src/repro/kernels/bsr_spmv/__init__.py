from .ops import bsr_spmv, ell_device_arrays  # noqa: F401
from .ref import ref_bsr_spmv  # noqa: F401
