"""Pure-jnp oracles for the ELL/SELL-BSR SpMV and SpMM kernels (same
inputs, same outputs)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def ref_bsr_spmv(block_indices: jax.Array, block_cols: jax.Array,
                 blocks: jax.Array, x_blocks: jax.Array) -> jax.Array:
    """y[i] = sum_j blocks[idx[i, j]] @ x_blocks[cols[i, j]]."""
    a = blocks[block_indices]          # (n_br, mb, bs, bs)
    xs = x_blocks[block_cols]          # (n_br, mb, bs)
    return jnp.einsum("rmab,rmb->ra", a, xs)


@jax.jit
def ref_bsr_spmm(block_indices: jax.Array, block_cols: jax.Array,
                 blocks: jax.Array, x_blocks: jax.Array) -> jax.Array:
    """Y[i] = sum_j blocks[idx[i, j]] @ x_blocks[cols[i, j]] (multi-RHS)."""
    a = blocks[block_indices]          # (n_br, mb, bs, bs)
    xs = x_blocks[block_cols]          # (n_br, mb, bs, k)
    return jnp.einsum("rmab,rmbk->rak", a, xs)


@functools.partial(jax.jit, static_argnames=("n_block_rows",))
def ref_bsr_spmv_sell(cell_block: jax.Array, cell_col: jax.Array,
                      cell_row: jax.Array, blocks: jax.Array,
                      x_blocks: jax.Array, n_block_rows: int) -> jax.Array:
    """y_sorted[r] = sum over cells t with cell_row[t] == r of
    blocks[cell_block[t]] @ x_blocks[cell_col[t]]."""
    prods = jnp.einsum("tab,tb->ta", blocks[cell_block], x_blocks[cell_col])
    return jax.ops.segment_sum(prods, cell_row, num_segments=n_block_rows)


@functools.partial(jax.jit, static_argnames=("n_block_rows",))
def ref_bsr_spmm_sell(cell_block: jax.Array, cell_col: jax.Array,
                      cell_row: jax.Array, blocks: jax.Array,
                      x_blocks: jax.Array, n_block_rows: int) -> jax.Array:
    """Multi-RHS form of ``ref_bsr_spmv_sell``: x_blocks is (n_bc, bs, k)."""
    prods = jnp.einsum("tab,tbk->tak", blocks[cell_block], x_blocks[cell_col])
    return jax.ops.segment_sum(prods, cell_row, num_segments=n_block_rows)
