"""Pure-jnp oracle for the ELL-BSR SpMV kernel (same inputs, same output)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def ref_bsr_spmv(block_indices: jax.Array, block_cols: jax.Array,
                 blocks: jax.Array, x_blocks: jax.Array) -> jax.Array:
    """y[i] = sum_j blocks[idx[i, j]] @ x_blocks[cols[i, j]]."""
    a = blocks[block_indices]          # (n_br, mb, bs, bs)
    xs = x_blocks[block_cols]          # (n_br, mb, bs)
    return jnp.einsum("rmab,rmb->ra", a, xs)
