"""Pallas TPU kernels for the sparse hot spots (DESIGN.md §3).

Callers should go through the plan/execute facade — ``repro.sparse.plan``
(DESIGN.md §8) — not these modules: the ``ops.py`` entry points are now
thin delegating shims kept for backward compatibility.

Each kernel directory has:
  kernel.py  pl.pallas_call + BlockSpec schedule (TPU target; validated in
             interpret mode on CPU); consumed by repro/sparse/ops_builtin
  ops.py     legacy entry-point shims (deprecated; delegate to the facade)
             + host helpers (symbolic phases, oracles, device exporters)
  ref.py     pure-jnp oracle

Kernels:
  bsr_spmv        ELL-BSR sparse matrix-vector product (paper Alg. 1, §4.4
                  ELL adaptation)
  bsr_spadd       branch-free block-union sparse add (paper Alg. 3)
  bsr_spgemm      Gustavson numeric phase over block pairs (paper Alg. 2)
  moe_gmm         ragged grouped GEMM for MoE expert compute (MegaBlocks-
                  style; the framework-integration of the paper's imbalance
                  analysis)
  flash_attention chunked online-softmax attention (prefill hot spot)
"""
from . import bsr_spmv, bsr_spadd, bsr_spgemm, moe_gmm, flash_attention  # noqa: F401
