"""Gustavson SpGEMM numeric phase as a Pallas block-pair GEMM (paper Alg. 2).

Gustavson scans rows of A and gathers rows of B ("scan-and-lookup", §3.4).
At block granularity the same dataflow is: for every output block C[i,j],
accumulate A[i,k] @ B[k,j] over the k's where both blocks exist. The host
symbolic phase (ops.spgemm_symbolic) enumerates those (a_idx, b_idx) pairs
in A-row-major order — *the* Gustavson schedule — padded per output block
to ``max_pairs`` with zero-block sentinels.

grid = (n_c_blocks, max_pairs), pair axis innermost: the C tile stays
resident in VMEM while its contributions stream through the MXU, giving the
temporal locality on C that the paper says CPU caches fail to provide for
B (the B-reuse problem becomes *A/B-tile streaming* + C-residency, which is
the TPU-correct formulation).

VMEM per cell: 3 tiles (A, B, C) x bs^2 x 4B x double-buffering; bs=128 ->
~400 KB. MXU does (bs x bs) @ (bs x bs) — full systolic utilization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spgemm_cells_kernel(ca_ref, cb_ref, cc_ref, a_ref, b_ref, c_ref):
    del ca_ref, cb_ref  # consumed by the index maps
    t = pl.program_id(0)
    first = jnp.logical_or(t == 0, cc_ref[t] != cc_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )[None]


@functools.partial(jax.jit, static_argnames=("n_c_blocks", "interpret"))
def bsr_spgemm_cells_pallas(cell_a: jax.Array, cell_b: jax.Array,
                            cell_c: jax.Array, a_blocks: jax.Array,
                            b_blocks: jax.Array, n_c_blocks: int,
                            interpret: bool = False) -> jax.Array:
    """Cell-flattened Gustavson numeric phase (the SELL trick applied to
    ragged block-pair lists, DESIGN.md §8): one grid step per REAL
    contribution pair instead of (n_c, max_pairs) with hub-padded slots.

    Args:
      cell_a/cell_b: (n_cells,) int32 — A/B tile of grid step t.
      cell_c: (n_cells,) int32 — output C block per step, *nondecreasing*
        (a C block's cells are consecutive), so the C tile stays resident
        and Pallas flushes it exactly when the block index advances.
      a_blocks/b_blocks: (n_a, bs, bs) / (n_b, bs, bs) f32 (no sentinel —
        there is no padding to point at one).
      n_c_blocks: static output block count.
    Returns:
      (n_c_blocks, bs, bs) float32.
    """
    n_cells = cell_a.shape[0]
    bs = a_blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_cells,),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda t, ca, cb, cc: (ca[t], 0, 0)),
            pl.BlockSpec((1, bs, bs), lambda t, ca, cb, cc: (cb[t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bs), lambda t, ca, cb, cc: (cc[t], 0, 0)),
    )
    return pl.pallas_call(
        _spgemm_cells_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_c_blocks, bs, bs), jnp.float32),
        interpret=interpret,
    )(cell_a, cell_b, cell_c, a_blocks, b_blocks)


def _spgemm_kernel(pa_ref, pb_ref, a_ref, b_ref, c_ref):
    del pa_ref, pb_ref
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spgemm_pallas(pair_a: jax.Array, pair_b: jax.Array,
                      a_blocks: jax.Array, b_blocks: jax.Array,
                      interpret: bool = False) -> jax.Array:
    """C.blocks[k] = sum_p a_blocks[pair_a[k, p]] @ b_blocks[pair_b[k, p]].

    Args:
      pair_a/pair_b: (n_c_blocks, max_pairs) int32; padding slots hold the
        zeros-sentinel index (last block of each array).
      a_blocks: (n_a + 1, bs, bs) f32; b_blocks: (n_b + 1, bs, bs) f32.
    Returns:
      (n_c_blocks, bs, bs) float32.
    """
    n_c, mp = pair_a.shape
    bs = a_blocks.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_c, mp),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda k, p, pa, pb: (pa[k, p], 0, 0)),
            pl.BlockSpec((1, bs, bs), lambda k, p, pa, pb: (pb[k, p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bs), lambda k, p, pa, pb: (k, 0, 0)),
    )
    return pl.pallas_call(
        _spgemm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_c, bs, bs), jnp.float32),
        interpret=interpret,
    )(pair_a, pair_b, a_blocks, b_blocks)
