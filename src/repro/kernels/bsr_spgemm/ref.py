"""Pure-jnp oracles for the SpGEMM numeric phase (padded pairs + flat cells)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def ref_pair_gemm(pair_a: jax.Array, pair_b: jax.Array, a_blocks: jax.Array,
                  b_blocks: jax.Array) -> jax.Array:
    a = a_blocks[pair_a]  # (n_c, mp, bs, bs)
    b = b_blocks[pair_b]  # (n_c, mp, bs, bs)
    return jnp.einsum("kpab,kpbc->kac", a, b)


@functools.partial(jax.jit, static_argnames=("n_c_blocks",))
def ref_cell_gemm(cell_a: jax.Array, cell_b: jax.Array, cell_c: jax.Array,
                  a_blocks: jax.Array, b_blocks: jax.Array,
                  n_c_blocks: int) -> jax.Array:
    """Cell-flattened numeric phase: C.blocks[c] = sum over cells t with
    cell_c[t] == c of a_blocks[cell_a[t]] @ b_blocks[cell_b[t]]."""
    prods = jnp.einsum("tab,tbc->tac", a_blocks[cell_a], b_blocks[cell_b])
    return jax.ops.segment_sum(prods, cell_c, num_segments=n_c_blocks)
