"""Pure-jnp oracle for the SpGEMM block-pair numeric phase."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def ref_pair_gemm(pair_a: jax.Array, pair_b: jax.Array, a_blocks: jax.Array,
                  b_blocks: jax.Array) -> jax.Array:
    a = a_blocks[pair_a]  # (n_c, mp, bs, bs)
    b = b_blocks[pair_b]  # (n_c, mp, bs, bs)
    return jnp.einsum("kpab,kpbc->kac", a, b)
