from .ops import bsr_spgemm, spgemm_symbolic  # noqa: F401
from .ref import ref_pair_gemm  # noqa: F401
