"""Public SpGEMM op: symbolic (host) + numeric (Pallas) phases (Alg. 2)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ...core.csr import CSR, BSR
from ..common import resolve_backend
from .kernel import bsr_spgemm_pallas
from .ref import ref_pair_gemm


def spgemm_symbolic(bsr_a: BSR, bsr_b: BSR) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray, np.ndarray]:
    """Symbolic phase (paper §2.1.3): C's block structure + contribution pairs.

    Returns (c_block_ptrs, c_block_cols, pair_a, pair_b) where pair_a/pair_b
    are (n_c_blocks, max_pairs) int32 padded with the zero-block sentinel.
    Pairs are enumerated in A-row-major order = Gustavson's scan order.
    """
    b_rows = {}
    for br in range(bsr_b.n_block_rows):
        lo, hi = int(bsr_b.block_ptrs[br]), int(bsr_b.block_ptrs[br + 1])
        b_rows[br] = {int(bsr_b.block_cols[k]): k for k in range(lo, hi)}
    c_cols_all, pairs_all = [], []
    c_ptrs = np.zeros(bsr_a.n_block_rows + 1, dtype=np.int64)
    for br in range(bsr_a.n_block_rows):
        contrib: dict = {}
        for k in range(int(bsr_a.block_ptrs[br]), int(bsr_a.block_ptrs[br + 1])):
            kk = int(bsr_a.block_cols[k])
            for cj, bidx in b_rows.get(kk, {}).items():
                contrib.setdefault(cj, []).append((k, bidx))
        for cj in sorted(contrib):
            c_cols_all.append(cj)
            pairs_all.append(contrib[cj])
        c_ptrs[br + 1] = len(c_cols_all)
    n_c = len(c_cols_all)
    mp = max((len(p) for p in pairs_all), default=1)
    a_sent, b_sent = bsr_a.n_blocks, bsr_b.n_blocks
    pair_a = np.full((n_c, mp), a_sent, dtype=np.int32)
    pair_b = np.full((n_c, mp), b_sent, dtype=np.int32)
    for i, plist in enumerate(pairs_all):
        for j, (ka, kb) in enumerate(plist):
            pair_a[i, j] = ka
            pair_b[i, j] = kb
    return c_ptrs, np.asarray(c_cols_all, np.int32), pair_a, pair_b


def bsr_spgemm(a: CSR, b: CSR, block_size: int = 128, backend: str = "auto",
               schedule=None) -> BSR:
    """C = A @ B via the block-pair Gustavson schedule; returns C as BSR.

    ``schedule``: an optional pre-selected ``core.autotune.Schedule`` (from
    the selector service); its block size overrides ``block_size``.
    """
    if schedule is not None:
        if schedule.backend == "dense":
            raise ValueError("dense schedules have no BSR path; dispatch a "
                             "dense matmul instead")
        block_size = schedule.block_size
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"inner dims mismatch {a.shape} @ {b.shape}")
    backend = resolve_backend(backend)
    bsr_a = BSR.from_csr(a, block_size)
    bsr_b = BSR.from_csr(b, block_size)
    c_ptrs, c_cols, pair_a, pair_b = spgemm_symbolic(bsr_a, bsr_b)
    bs = block_size
    a_blocks = jnp.concatenate(
        [jnp.asarray(bsr_a.blocks), jnp.zeros((1, bs, bs), jnp.float32)])
    b_blocks = jnp.concatenate(
        [jnp.asarray(bsr_b.blocks), jnp.zeros((1, bs, bs), jnp.float32)])
    if pair_a.shape[0] == 0:
        c_blocks = np.zeros((0, bs, bs), np.float32)
    elif backend == "jnp":
        c_blocks = np.asarray(ref_pair_gemm(
            jnp.asarray(pair_a), jnp.asarray(pair_b), a_blocks, b_blocks))
    else:
        c_blocks = np.asarray(bsr_spgemm_pallas(
            jnp.asarray(pair_a), jnp.asarray(pair_b), a_blocks, b_blocks,
            interpret=(backend == "interpret")))
    return BSR(c_ptrs, c_cols, c_blocks, (a.shape[0], b.shape[1]), block_size)
