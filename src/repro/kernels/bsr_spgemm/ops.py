"""SpGEMM symbolic phase (host, vectorized) + the legacy entry-point shim.

Two numeric schedules (the op's ``layout`` axis in the facade registry):
  ell    block-pairs padded per output block to ``max_pairs`` — one hub
         output block pads every other block's pair list (kernel.py grid
         (n_c, max_pairs)).
  sell   the SELL cell-flattening trick applied to the ragged Gustavson
         block-rows: the (output block, pair) schedule is flattened to one
         grid step per real pair — zero padding, ragged work becomes grid
         steps that never launch (kernel.py grid (n_cells,)).

The symbolic phase is pure numpy bulk ops (np.repeat / argsort / unique) —
no per-row Python loops; host prep is on the serving path.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ...core.csr import CSR, BSR


def _gustavson_join(bsr_a: BSR, bsr_b: BSR
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All (a_block, b_block) contribution pairs in A-row-major order
    (= Gustavson's scan order), as flat arrays (pair_a, pair_b, c_key)
    where c_key = c_block_row * n_bc_c + c_block_col."""
    n_bc_c = -(-bsr_b.shape[1] // bsr_b.block_size)
    if bsr_a.n_blocks == 0 or bsr_b.n_block_rows == 0:
        z = np.zeros(0, np.int64)
        return z, z, z
    a_rows = np.repeat(np.arange(bsr_a.n_block_rows, dtype=np.int64),
                       bsr_a.blocks_per_row())
    a_cols = bsr_a.block_cols.astype(np.int64)
    b_bpr = bsr_b.blocks_per_row()
    safe = np.minimum(a_cols, bsr_b.n_block_rows - 1)
    cnt = np.where(a_cols < bsr_b.n_block_rows, b_bpr[safe], 0)
    total = int(cnt.sum())
    pa = np.repeat(np.arange(bsr_a.n_blocks, dtype=np.int64), cnt)
    starts = np.concatenate([[0], np.cumsum(cnt)])
    pb = (np.arange(total, dtype=np.int64) - np.repeat(starts[:-1], cnt)
          + np.repeat(bsr_b.block_ptrs[safe], cnt))
    c_key = np.repeat(a_rows, cnt) * n_bc_c + bsr_b.block_cols[pb]
    return pa, pb, c_key


def _group_pairs(bsr_a: BSR, bsr_b: BSR):
    """Join + stable group-by output block. Returns (c_ptrs, c_cols, gid,
    pos, pa, pb, n_c) with pairs sorted by output block, Gustavson order
    preserved inside each group (stable sort)."""
    pa, pb, c_key = _gustavson_join(bsr_a, bsr_b)
    n_bc_c = -(-bsr_b.shape[1] // bsr_b.block_size)
    order = np.argsort(c_key, kind="stable")
    key_s, pa_s, pb_s = c_key[order], pa[order], pb[order]
    uk, first, counts = np.unique(key_s, return_index=True,
                                  return_counts=True)
    n_c = int(uk.size)
    gid = np.repeat(np.arange(n_c, dtype=np.int64), counts)
    pos = np.arange(key_s.size, dtype=np.int64) - np.repeat(first, counts)
    c_cols = (uk % n_bc_c).astype(np.int32)
    c_rows = uk // n_bc_c
    c_ptrs = np.zeros(bsr_a.n_block_rows + 1, dtype=np.int64)
    np.add.at(c_ptrs, c_rows + 1, 1)
    c_ptrs = np.cumsum(c_ptrs)
    return c_ptrs, c_cols, gid, pos, pa_s, pb_s, n_c


def spgemm_symbolic(bsr_a: BSR, bsr_b: BSR) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray, np.ndarray]:
    """Symbolic phase (paper §2.1.3): C's block structure + contribution pairs.

    Returns (c_block_ptrs, c_block_cols, pair_a, pair_b) where pair_a/pair_b
    are (n_c_blocks, max_pairs) int32 padded with the zero-block sentinel.
    Pairs are enumerated in A-row-major order = Gustavson's scan order.
    """
    c_ptrs, c_cols, gid, pos, pa, pb, n_c = _group_pairs(bsr_a, bsr_b)
    mp = int(pos.max()) + 1 if pos.size else 1
    pair_a = np.full((n_c, mp), bsr_a.n_blocks, dtype=np.int32)
    pair_b = np.full((n_c, mp), bsr_b.n_blocks, dtype=np.int32)
    pair_a[gid, pos] = pa
    pair_b[gid, pos] = pb
    return c_ptrs, c_cols, pair_a, pair_b


def spgemm_symbolic_cells(bsr_a: BSR, bsr_b: BSR
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]:
    """Cell-flattened symbolic phase: the SELL trick on Gustavson block-rows.

    Returns (c_block_ptrs, c_block_cols, cell_a, cell_b, cell_c): one cell
    per REAL contribution pair — no pair padding at all. ``cell_c`` is
    nondecreasing (a C block's cells are consecutive), the output-residency
    contract of the Pallas cells kernel, mirroring SELLBSR.cell_row.
    """
    c_ptrs, c_cols, gid, _, pa, pb, _ = _group_pairs(bsr_a, bsr_b)
    return (c_ptrs, c_cols, pa.astype(np.int32), pb.astype(np.int32),
            gid.astype(np.int32))


def bsr_spgemm(a: CSR, b: CSR, block_size: int = 128, backend: str = "auto",
               schedule=None) -> BSR:
    """C = A @ B; returns C as BSR.

    .. deprecated:: use ``repro.sparse.plan("spgemm", (a, b), ...)`` — this
       shim delegates there (DESIGN.md §8 migration table).
    """
    from ...sparse import plan
    return plan("spgemm", (a, b), schedule=schedule, backend=backend,
                block_size=block_size).execute()
