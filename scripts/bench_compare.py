#!/usr/bin/env python
"""Diff two bench JSONs (benchmarks/run.py --json) and flag regressions.

The committed BENCH_*.json files are the repo's perf trajectory (ROADMAP
item 3); this tool is the regression edge between any two of them:

    python scripts/bench_compare.py BENCH_0007.json fresh.json
    python scripts/bench_compare.py BENCH_0007.json fresh.json --strict
    python scripts/bench_compare.py latest fresh.json

``latest`` as the baseline resolves to the highest-numbered committed
``BENCH_NNNN.json`` next to this script's repo root — CI jobs compare a
fresh run against the newest trajectory point without hardcoding its name
into the workflow (which would silently pin the gate to a stale baseline
as new points land).

Rows present in both files are compared on ``us`` (microseconds per call):
a row slower by more than ``--threshold`` (default 0.25 = +25%) is flagged
as a regression, faster by the same margin as an improvement. Added and
removed rows are listed, never flagged — a partial run (smoke compares the
selector module against the full committed trajectory) is expected to miss
most rows. ``/elapsed`` bookkeeping rows are skipped: they time whole
modules, including fit sweeps whose size legitimately changes run to run.

Exit code is 0 unless ``--strict`` is passed AND regressions were found —
wall-clock on shared CI runners is noisy, so the default mode is a report,
not a gate (flip on --strict once the trajectory has enough points to
separate noise from drift).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List, Tuple


def resolve_latest(search_dir: str = None) -> str:
    """Highest-numbered BENCH_NNNN.json in the repo root (the newest
    committed trajectory point)."""
    root = search_dir or os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))
    candidates = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
        if m:
            candidates.append((int(m.group(1)), path))
    if not candidates:
        raise SystemExit(f"--baseline latest: no BENCH_NNNN.json in {root}")
    return max(candidates)[1]


def load(path: str) -> Dict[str, Dict]:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise SystemExit(f"{path}: not a bench JSON object")
    return {k: v for k, v in data.items()
            if isinstance(v, dict) and isinstance(v.get("us"), (int, float))}


def compare(base: Dict[str, Dict], new: Dict[str, Dict],
            threshold: float) -> Tuple[List[Tuple[str, float, float, float]],
                                       List[Tuple[str, float, float, float]]]:
    """(regressions, improvements) as (name, base_us, new_us, ratio)."""
    regressions, improvements = [], []
    for name in sorted(set(base) & set(new)):
        if name.endswith("/elapsed"):
            continue
        b, n = float(base[name]["us"]), float(new[name]["us"])
        if b <= 0.0:
            continue
        ratio = n / b
        if ratio > 1.0 + threshold:
            regressions.append((name, b, n, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, b, n, ratio))
    return regressions, improvements


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("base", help="baseline bench JSON (e.g. BENCH_0007.json),"
                    " or 'latest' for the highest-numbered committed"
                    " BENCH_NNNN.json")
    ap.add_argument("new", help="fresh bench JSON to compare")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative slowdown that counts as a regression "
                         "(default 0.25 = +25%%)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when regressions are found")
    args = ap.parse_args(argv)

    base_path = (resolve_latest() if args.base == "latest" else args.base)
    if base_path != args.base:
        print(f"bench_compare: baseline 'latest' -> {base_path}")
    base, new = load(base_path), load(args.new)
    shared = set(base) & set(new)
    added = sorted(set(new) - set(base))
    removed = sorted(set(base) - set(new))
    regressions, improvements = compare(base, new, args.threshold)

    print(f"bench_compare: {len(shared)} shared rows "
          f"({len(added)} only in new, {len(removed)} only in base), "
          f"threshold +{args.threshold:.0%}")
    for name, b, n, ratio in regressions:
        print(f"  REGRESSION {name}: {b:.1f}us -> {n:.1f}us "
              f"({ratio:.2f}x)")
    for name, b, n, ratio in improvements:
        print(f"  improved   {name}: {b:.1f}us -> {n:.1f}us "
              f"({ratio:.2f}x)")
    if not regressions and not improvements:
        print(f"  no rows moved past the threshold")
    if added:
        print(f"  new rows: {', '.join(added[:8])}"
              + (" ..." if len(added) > 8 else ""))
    if removed:
        print(f"  missing rows (partial run?): {len(removed)}")
    if regressions and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
