#!/usr/bin/env bash
# Smoke gate: tier-1 tests + a 10-request selector serve + bench JSON shape.
# Usage: scripts/smoke.sh [--fast]   (--fast skips the full tier-1 suite and
# runs the selector/counter/schema slice only)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
  python -m pytest -x -q tests/test_selector.py tests/test_counters_lru.py \
    tests/test_bench_schema.py
else
  python -m pytest -x -q
fi

# 10-request selector smoke run (held-out corpus, cache persisted + reloaded)
tmpdir="$(mktemp -d)"
trap 'rm -rf "$tmpdir"' EXIT
python -m repro.selector.serve --requests 10 --train-mats 9 --serve-mats 5 \
  --n-min 256 --n-max 384 --batch 4 --cache-path "$tmpdir/cache.json"
test -s "$tmpdir/cache.json"

# benchmark JSON trajectory emission stays machine-readable
python -m benchmarks.run selector --json "$tmpdir/bench.json"
python - "$tmpdir/bench.json" <<'PY'
import json, sys
data = json.load(open(sys.argv[1]))
assert data and all(set(r) == {"us", "derived"} for r in data.values()), data
print(f"smoke OK: {len(data)} bench rows")
PY
