#!/usr/bin/env bash
# Smoke gate: tier-1 tests + a 10-request selector serve + bench JSON shape.
# Usage: scripts/smoke.sh [--fast]   (--fast skips the full tier-1 suite and
# runs the selector/counter/schema slice only)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
  python -m pytest -x -q tests/test_selector.py tests/test_counters_lru.py \
    tests/test_bench_schema.py tests/test_serving_path.py
else
  python -m pytest -x -q
fi

# 10-request selector smoke run (held-out corpus, cache persisted + reloaded)
tmpdir="$(mktemp -d)"
trap 'rm -rf "$tmpdir"' EXIT
python -m repro.selector.serve --requests 10 --train-mats 9 --serve-mats 5 \
  --n-min 256 --n-max 384 --batch 4 --cache-path "$tmpdir/cache.json"
test -s "$tmpdir/cache.json"

# plan()-path smoke: selector-backed SpMV through the facade (DESIGN.md §8)
python - <<'PY'
import numpy as np
from repro.core import ScheduleTuner, TPU_V5E, corpus
from repro.core.synthetic import gen_zipf
from repro.selector import ScheduleCache, SelectorService
from repro.sparse import launch_count, plan, reset_counters

tuner = ScheduleTuner("spmv", TPU_V5E).fit(
    corpus(n_matrices=9, n_min=256, n_max=384, seed=3), max_mats=9)
svc = SelectorService(tuner, cache=ScheduleCache())
A = gen_zipf(300, seed=1)
x = np.random.default_rng(0).standard_normal(300).astype(np.float32)
reset_counters()
p = plan("spmv", (A,), selector=svc)
y = np.asarray(p.execute(x))
assert y.shape == (300,) and np.isfinite(y).all()
assert launch_count("spmv") == 1
assert plan("spmv", (A,), selector=svc).source == "selector-cache"
np.testing.assert_allclose(y, A.to_dense() @ x, rtol=2e-4, atol=2e-4)
print(f"plan smoke OK: {p.describe()} (source={p.source})")
PY

# benchmark JSON trajectory emission stays machine-readable; BENCH_JSON_OUT
# (set by CI) persists it so the workflow can upload it as an artifact
bench_json="${BENCH_JSON_OUT:-$tmpdir/bench.json}"
python -m benchmarks.run selector --json "$bench_json"
python - "$bench_json" <<'PY'
import json, sys
data = json.load(open(sys.argv[1]))
assert data and all(set(r) == {"us", "derived"} for r in data.values()), data
print(f"smoke OK: {len(data)} bench rows")
PY

# zero-rebuild serving rows (DESIGN.md §9): the warm/cold plan_build bench
# rows must exist, prove the PreparedStore path via hit counters, and show
# a real warm speedup (>=3x here; the acceptance-level >=10x is tracked by
# the bench rows themselves and is typically 16-50x on an idle machine)
micro_json="${BENCH_MICRO_JSON_OUT:-$tmpdir/bench_micro.json}"
python -m benchmarks.run kernels_micro --json "$micro_json"
python - "$micro_json" <<'PY'
import json, sys
data = json.load(open(sys.argv[1]))
warm = {k: v for k, v in data.items() if k.startswith("plan_build_warm/")}
assert {"plan_build_warm/spmv", "plan_build_warm/spadd",
        "plan_build_warm/spgemm"} <= set(warm), sorted(data)
for name, rec in sorted(warm.items()):
    stats = dict(kv.split("=") for kv in rec["derived"].split(";") if "=" in kv)
    assert int(stats["hits"]) > 0, (name, rec)          # cached path taken
    speedup = float(stats["speedup"].rstrip("x"))
    assert speedup >= 3.0, (name, rec)                  # warm >> cold
    print(f"{name}: {rec['us']:.0f}us warm, {stats['speedup']} vs cold")
print("zero-rebuild smoke OK")
PY
