#!/usr/bin/env bash
# Smoke gate: tier-1 tests + a 10-request selector serve + bench JSON shape.
# Usage: scripts/smoke.sh [--fast]   (--fast skips the full tier-1 suite and
# runs the selector/counter/schema slice only)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--fast" ]]; then
  python -m pytest -x -q tests/test_selector.py tests/test_counters_lru.py \
    tests/test_bench_schema.py tests/test_serving_path.py \
    tests/test_serving_engine.py tests/test_resilience.py
else
  python -m pytest -x -q
fi

# 10-request selector smoke run (held-out corpus, cache persisted + reloaded)
tmpdir="$(mktemp -d)"
trap 'rm -rf "$tmpdir"' EXIT
python -m repro.selector.serve --requests 10 --train-mats 9 --serve-mats 5 \
  --n-min 256 --n-max 384 --batch 4 --cache-path "$tmpdir/cache.json"
test -s "$tmpdir/cache.json"

# plan()-path smoke: selector-backed SpMV through the facade (DESIGN.md §8)
python - <<'PY'
import numpy as np
from repro.core import ScheduleTuner, TPU_V5E, corpus
from repro.core.synthetic import gen_zipf
from repro.selector import ScheduleCache, SelectorService
from repro.sparse import launch_count, plan, reset_counters

tuner = ScheduleTuner("spmv", TPU_V5E).fit(
    corpus(n_matrices=9, n_min=256, n_max=384, seed=3), max_mats=9)
svc = SelectorService(tuner, cache=ScheduleCache())
A = gen_zipf(300, seed=1)
x = np.random.default_rng(0).standard_normal(300).astype(np.float32)
reset_counters()
p = plan("spmv", (A,), selector=svc)
y = np.asarray(p.execute(x))
assert y.shape == (300,) and np.isfinite(y).all()
assert launch_count("spmv") == 1
assert plan("spmv", (A,), selector=svc).source == "selector-cache"
np.testing.assert_allclose(y, A.to_dense() @ x, rtol=2e-4, atol=2e-4)
print(f"plan smoke OK: {p.describe()} (source={p.source})")
PY

# sharded execution smoke (DESIGN.md §10): the equivalence + partitioner
# suite re-runs under 4 simulated host devices (the dryrun.py pattern), so
# the shard_map path — not just the 1-device fallback — is exercised; then
# the bench family must prove nnz-balanced splits strictly beat equal-row
# splits on the skewed matrix (the acceptance criterion, machine-checked)
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
  python -m pytest -x -q tests/test_sharded.py
sharded_json="${BENCH_SHARDED_JSON_OUT:-$tmpdir/bench_sharded.json}"
python -m benchmarks.run sharded --json "$sharded_json"
python - "$sharded_json" <<'PY'
import json, sys
data = json.load(open(sys.argv[1]))
for d in (2, 4, 8):
    stats = {}
    for strat in ("nnz", "rows"):
        rec = data[f"sharded/{strat}_d{d}"]
        stats[strat] = dict(kv.split("=") for kv in rec["derived"].split(";"))
    nnz_max, rows_max = (float(stats[s]["imb_max"]) for s in ("nnz", "rows"))
    assert nnz_max < rows_max, (d, stats)   # strictly lower max-shard Eq.5
    print(f"sharded d={d}: imb_max nnz={nnz_max:.4f} < rows={rows_max:.4f}")
print("sharded smoke OK")
PY

# chaos smoke (DESIGN.md §11): the recovery-path suite, then a 32-request
# serve under a 20% deterministic fault rate across every injection site.
# The machine-checked acceptance bar: every request completes, every served
# output matches the reference, zero unhandled exceptions escape, the
# telemetry accounts for every injected fault (fired == recovered), and the
# fallback ladder actually engaged at least once (seed 7 guarantees it).
# The serve also records itself (--trace-out, DESIGN.md §12): the Chrome
# trace must parse with only non-negative complete events, the JSONL event
# log must carry >=1 select, >=1 launch and — under this fault rate — >=1
# fallback event, and the event total must reconcile with the telemetry.
# SMOKE_TRACE_OUT (set by CI) persists the trace as a workflow artifact.
python -m pytest -x -q -m chaos tests/test_resilience.py
SMOKE_TRACE_OUT="${SMOKE_TRACE_OUT:-$tmpdir/chaos_trace.json}" python - <<'PY'
import json, os
from repro.selector.serve import main
trace_out = os.environ["SMOKE_TRACE_OUT"]
tel = main(["--requests", "32", "--train-mats", "9", "--serve-mats", "5",
            "--n-min", "256", "--n-max", "384", "--batch", "8", "--execute",
            "--fault-rate", "0.2", "--fault-seed", "7",
            "--trace-out", trace_out,
            "--metrics-out", os.path.splitext(trace_out)[0] + "_metrics.json"])
assert tel["fault_fired"] > 0, tel
assert tel["fault_fired"] == tel["fault_recovered"], tel
assert tel["guard_fallbacks"] >= 1, tel
assert tel["exec_checked"] > 0 and tel["exec_mismatches"] == 0, tel
assert tel["requests"] == 32.0, tel
trace = json.load(open(trace_out))
evs = trace["traceEvents"]
assert evs and all(e["ph"] == "X" and e["dur"] >= 0 for e in evs), "bad trace"
assert tel["trace_events"] == float(len(evs)), (tel["trace_events"], len(evs))
counts = {}
with open(os.path.splitext(trace_out)[0] + ".jsonl") as f:
    for line in f:
        ev = json.loads(line)
        counts[ev["type"]] = counts.get(ev["type"], 0) + 1
assert counts.get("select", 0) >= 1, counts
assert counts.get("launch", 0) >= 1, counts
assert counts.get("fallback", 0) >= 1, counts   # the ladder engaged
print(f"chaos smoke OK: {tel['fault_fired']:.0f} faults fired, "
      f"{tel['fault_recovered']:.0f} recovered, "
      f"{tel['guard_fallbacks']:.0f} fallbacks, "
      f"{tel['exec_checked']:.0f} outputs verified")
print(f"trace smoke OK: {len(evs)} events "
      + "  ".join(f"{k}={v}" for k, v in sorted(counts.items())))
PY

# mutation-chaos smoke (DESIGN.md §14): a churn loop — value deltas plus
# structural-insert pressure against a warm PreparedStore — under a 20%
# deterministic fault rate on the two mutation sites (delta-apply,
# slack-overflow). Machine-checked: every injected fault is recovered
# (fired == recovered), at least one epoch-swap rebuild engaged (the
# slack=1 container cannot absorb the insert stream), and every
# post-mutation result still matches the dense reference through the warm
# store — degradation never serves a stale or wrong answer.
python - <<'PY'
import numpy as np
from repro.core import CSR
from repro.sparse import (Delta, FaultInjector, MutableMatrix, PreparedStore,
                          install_injector, plan, reset_resilience)
rng = np.random.default_rng(11)
n = 96
d = (rng.random((n, n)) < 0.03) * rng.standard_normal((n, n))
A = CSR.from_dense(d.astype(np.float32))
x = rng.standard_normal(n).astype(np.float32)
inj = FaultInjector(rate=0.2, seed=7,
                    sites=("delta-apply", "slack-overflow"))
install_injector(inj)
store = PreparedStore()
mm = MutableMatrix(A, store=store, slack=1)
plan("spmv", (A,), backend="jnp", store=store, block_size=8).execute(x)
dense = np.asarray(A.to_dense())
empty = np.argwhere(~dense.reshape(n // 8, 8, n // 8, 8).any(axis=(1, 3)))
for step in range(24):
    if step % 3 == 2 and len(empty):
        k = min(4, len(empty))          # insert pressure -> epoch swap
        pos = empty[:k] * 8
        empty = empty[k:]
        mm.apply_delta(Delta(pos[:, 0], pos[:, 1],
                             np.ones(k, np.float32)))
    else:
        lens = np.diff(A.row_ptrs)
        rows = np.repeat(np.arange(n), lens)
        pick = rng.choice(rows.size, size=8, replace=False)
        mm.apply_delta(Delta(rows[pick], A.col_idxs[pick].astype(np.int64),
                             rng.standard_normal(8).astype(np.float32)))
    y = np.asarray(plan("spmv", (A,), backend="jnp", store=store,
                        block_size=8).execute(x))
    np.testing.assert_allclose(y, np.asarray(A.to_dense()) @ x,
                               rtol=2e-5, atol=2e-5)
t = inj.telemetry()
mt = dict(mm.telemetry())
assert t["fault_fired"] > 0, t
assert t["fault_fired"] == t["fault_recovered"], t
assert mt["epoch_swaps"] >= 1 and mt["rebuilds"] >= 1, mt
reset_resilience()
print(f"mutation chaos OK: {t['fault_fired']:.0f} faults fired == "
      f"{t['fault_recovered']:.0f} recovered, "
      f"{mt['epoch_swaps']:.0f} epoch swaps, "
      f"{mt['rebuilds']:.0f} rebuilds, generation {mt['generation']}")
PY

# serving smoke (DESIGN.md §13): a 48-request Zipf burst through the
# continuous-batching engine. Machine-checked: the ledger identity
# admitted == completed + shed holds exactly, at least one drain stacked
# multiple requests into one launch (the batching engine actually batched),
# and the recorded enqueue/admit/drain event counts reconcile with the
# engine's registry-backed telemetry — the ISSUE's acceptance bar.
python - <<'PY'
import json, os, tempfile
from repro.serving.serve import main
tmp = tempfile.mkdtemp()
trace_out = os.path.join(tmp, "serve_trace.json")
rep = main(["--requests", "48", "--qps", "800", "--tenants", "4",
            "--train-mats", "9", "--n-min", "256", "--n-max", "384",
            "--slot-max", "8", "--deadline-ms", "4000", "--slo-ms", "50",
            "--trace-out", trace_out, "--seed", "17"])
assert rep["admitted"] == rep["completed"] + rep["shed"], rep
assert rep["completed"] + rep["shed"] + rep["rejected"] == 48.0, rep
assert rep["multi_request_drains"] >= 1, rep       # batching engaged
counts = {}
with open(os.path.splitext(trace_out)[0] + ".jsonl") as f:
    for line in f:
        ev = json.loads(line)
        counts[ev["type"]] = counts.get(ev["type"], 0) + 1
assert counts.get("enqueue", 0) == rep["submitted"], (counts, rep)
assert counts.get("admit", 0) == rep["admitted"], (counts, rep)
assert counts.get("drain", 0) == rep["drains"], (counts, rep)
print(f"serving smoke OK: {rep['completed']:.0f} completed / "
      f"{rep['shed']:.0f} shed / {rep['rejected']:.0f} rejected, "
      f"{rep['multi_request_drains']:.0f} multi-request drains, "
      f"occupancy {rep['mean_drain_size']:.1f}, "
      f"p99 {rep['latency_p99_ms']:.0f}ms")
PY

# crash-recovery smoke (DESIGN.md §15): a seeded Zipf trace through the
# DURABLE serve path — WAL journal + periodic checkpoints — with the crash
# fault site armed (seed 8 fires on the very first crash check, so the
# replay is killed mid-flight at least once and restarts under the
# supervisor). Machine-checked: zero journaled-admitted requests lost
# (ledger open == 0), nothing executed twice (duplicate_outcomes == 0),
# the cross-incarnation journal ledger closes exactly over the trace, the
# final registry holds admitted == completed + shed, and every injected
# fault — crashes included — is recovered (fired == recovered).
python - <<'PY'
import json, os, tempfile
from repro.serving.serve import main
from repro.serving import RequestJournal, reconcile
tmp = tempfile.mkdtemp()
ckdir = os.path.join(tmp, "durable")
trace_out = os.path.join(tmp, "crash_trace.json")
rep = main(["--requests", "32", "--qps", "800", "--tenants", "4",
            "--train-mats", "9", "--n-min", "256", "--n-max", "384",
            "--checkpoint-dir", ckdir, "--checkpoint-every", "4",
            "--max-restarts", "25", "--trace-out", trace_out,
            "--fault-rate", "0.05", "--fault-seed", "8", "--seed", "17"])
assert rep["recovery_restarts"] >= 1, rep          # a crash really happened
assert rep["fault_fired"] == rep["fault_recovered"], rep
assert rep["admitted"] == rep["completed"] + rep["shed"], rep
led = reconcile(RequestJournal(os.path.join(ckdir, "journal")).scan())
assert led["open"] == 0, led                       # no admitted request lost
assert led["duplicate_outcomes"] == 0, led         # nothing answered twice
assert led["submitted"] == 32.0, led               # the whole trace is WALed
assert led["submitted"] == (led["completed"] + led["shed"]
                            + led["rejected"]), led
# the journal's distinct-rid view is a superset of the final incarnation's
# registry: work a crashed incarnation finished after its last checkpoint
# is terminal in the WAL and deduped (not re-counted) after restore
assert led["completed"] >= rep["completed"], (led, rep)
# trace-vs-registry reconciliation: the recorded restart / recovery /
# checkpoint events must match the recovery telemetry exactly — one
# restart event per caught crash, one recovery event per incarnation
counts = {}
with open(os.path.splitext(trace_out)[0] + ".jsonl") as f:
    for line in f:
        ev = json.loads(line)
        counts[ev["type"]] = counts.get(ev["type"], 0) + 1
assert counts.get("restart", 0) == rep["recovery_restarts"], (counts, rep)
assert counts.get("recovery", 0) == rep["recovery_restarts"] + 1, counts
assert counts.get("checkpoint", 0) >= 1, counts
print(f"crash smoke OK: {rep['recovery_restarts']:.0f} restarts, "
      f"{rep['recovery_replayed']:.0f} replayed, mttr "
      f"{rep['recovery_mttr_ms']:.0f}ms, ledger open {led['open']:.0f}, "
      f"dup {led['duplicate_outcomes']:.0f}, "
      f"events restart={counts.get('restart', 0)} "
      f"recovery={counts.get('recovery', 0)} "
      f"checkpoint={counts.get('checkpoint', 0)}")
PY

# benchmark JSON trajectory emission stays machine-readable; BENCH_JSON_OUT
# (set by CI) persists it so the workflow can upload it as an artifact
bench_json="${BENCH_JSON_OUT:-$tmpdir/bench.json}"
python -m benchmarks.run selector --json "$bench_json"
python - "$bench_json" <<'PY'
import json, sys
data = json.load(open(sys.argv[1]))
assert data and all(set(r) == {"us", "derived"} for r in data.values()), data
print(f"smoke OK: {len(data)} bench rows")
PY

# perf-trajectory diff vs the newest committed BENCH_NNNN.json point
# (non-fatal: bench_compare reports >25% moves but exits 0 without --strict
# — shared runners are too noisy for a hard wall-clock gate in the smoke
# path). 'latest' resolves so new trajectory points never stale-pin this.
python scripts/bench_compare.py latest "$bench_json" || true

# zero-rebuild serving rows (DESIGN.md §9): the warm/cold plan_build bench
# rows must exist, prove the PreparedStore path via hit counters, and show
# a real warm speedup (>=3x here; the acceptance-level >=10x is tracked by
# the bench rows themselves and is typically 16-50x on an idle machine)
micro_json="${BENCH_MICRO_JSON_OUT:-$tmpdir/bench_micro.json}"
python -m benchmarks.run kernels_micro --json "$micro_json"
python - "$micro_json" <<'PY'
import json, sys
data = json.load(open(sys.argv[1]))
warm = {k: v for k, v in data.items() if k.startswith("plan_build_warm/")}
assert {"plan_build_warm/spmv", "plan_build_warm/spadd",
        "plan_build_warm/spgemm"} <= set(warm), sorted(data)
for name, rec in sorted(warm.items()):
    stats = dict(kv.split("=") for kv in rec["derived"].split(";") if "=" in kv)
    assert int(stats["hits"]) > 0, (name, rec)          # cached path taken
    speedup = float(stats["speedup"].rstrip("x"))
    assert speedup >= 3.0, (name, rec)                  # warm >> cold
    print(f"{name}: {rec['us']:.0f}us warm, {stats['speedup']} vs cold")
print("zero-rebuild smoke OK")
PY
